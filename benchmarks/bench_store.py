"""Tiered store benchmark: warm restart vs cold start, cost-aware vs LRU.

Two experiments:

* **Warm restart** — a Zipfian dashboard stream runs against a service with
  a durable store (``open``/write-through).  The process is then "killed"
  (the store is abandoned un-closed: durability comes from the WAL, not a
  graceful shutdown) and a fresh service ``open``s the same directory.  The
  metric is time-to-hit-rate: how many requests each run needs before its
  rolling hit rate reaches 80% of the cold run's steady state.  Acceptance:
  the warm restart gets there in <= 20% of the cold run's request count.

* **Cost-aware vs LRU** — the same Zipfian mix replayed through two
  byte-budgeted caches that differ only in eviction policy (no store: an
  eviction is a real drop, so the A/B isolates the victim choice).  Under a
  budget far below the population's footprint, LRU cycles the tail through
  the cache while the cost policy pins the popular, expensive-to-recompute
  head.  Reported per policy: hit rate, hit-bytes-served (bytes answered
  from cache rather than recomputed), and recompute milliseconds paid.
  Acceptance: cost-aware serves more hit-bytes than LRU.

Writes ``BENCH_store.json``.

    PYTHONPATH=src python benchmarks/bench_store.py           # full run
    PYTHONPATH=src python benchmarks/bench_store.py --quick   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")

# grouping granularities give the population a real size spread (c_city
# tables are ~50x c_region ones), measure blocks give it distinct families
GROUPS = ("c_region", "c_nation", "c_city")
MEASURES = ("SUM(lo_revenue) AS rev",
            "SUM(lo_revenue) AS rev, COUNT(*) AS n",
            "MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi")
YEARS = (1992, 1993, 1994, 1995)


def build_population(n: int) -> list[str]:
    """The first ``n`` queries of a deterministic group x measure x year
    grid, ordered so sizes and families interleave."""
    grid = [f"SELECT {g}, {m} FROM lineorder {JOINS}"
            f"WHERE d_year = {y} GROUP BY {g}"
            for y in YEARS for g in GROUPS for m in MEASURES]
    return grid[:n]


def zipf_stream(n_queries: int, length: int, seed: int, s: float = 0.8) -> list[int]:
    """Zipfian index stream: rank-r query drawn with weight 1/r^s.  The
    default skew keeps a popular head without letting two or three queries
    dominate — a cold cache must actually discover the population."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_queries + 1) ** s
    return list(rng.choice(n_queries, size=length, p=w / w.sum()))


def reach_requests(hits: list[bool], target: float, min_n: int = 5) -> int | None:
    """First request count ``i >= min_n`` whose cumulative hit rate reaches
    ``target``.  Cumulative (not windowed) so the early misses of a cold
    start drag the curve the way they drag a real dashboard's first paint —
    and so the measurement floor is ``min_n``, not a window width."""
    acc = 0
    for i, h in enumerate(hits, start=1):
        acc += h
        if i >= min_n and acc / i >= target:
            return i
    return None


# ------------------------------------------------------------ warm restart


def run_stream(svc, queries, stream) -> list[bool]:
    from repro.service import QueryRequest

    hits = []
    for qi in stream:
        r = svc.submit(QueryRequest(sql=queries[qi], tenant="t"))
        hits.append(r.status != "miss")
    return hits


def make_service(wl):
    from repro.core import SemanticCache
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService

    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema,
        backend=OlapExecutor(wl.dataset, impl="numpy"),
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper()))
    return svc


def warm_restart_experiment(wl, queries, stream, root: str) -> dict:
    window = max(10, len(stream) // 20)

    # cold start: empty store, Zipfian stream, write-through spills
    svc = make_service(wl)
    svc.open(root)
    t0 = time.perf_counter()
    cold_hits = run_stream(svc, queries, stream)
    cold_s = time.perf_counter() - t0
    steady = sum(cold_hits[-window:]) / window
    target = 0.8 * steady
    cold_reach = reach_requests(cold_hits, target)
    # "kill": drain the write-behind queue, then abandon without close() —
    # recovery must come from the WAL, not a graceful checkpoint
    store = svc.tenant("t").cache.store
    store.flush()
    del svc

    svc2 = make_service(wl)
    adopted = svc2.open(root)["t"]
    t0 = time.perf_counter()
    warm_hits = run_stream(svc2, queries, stream)
    warm_s = time.perf_counter() - t0
    warm_reach = reach_requests(warm_hits, target)
    tiers = svc2.stats("t")["tiers"]
    svc2.close()

    res = {
        "population": len(queries),
        "requests": len(stream),
        "window": window,
        "steady_state_hit_rate": round(steady, 3),
        "target_hit_rate": round(target, 3),
        "cold": {"reach_requests": cold_reach,
                 "hit_rate": round(sum(cold_hits) / len(cold_hits), 3),
                 "elapsed_s": round(cold_s, 3)},
        "warm": {"adopted_entries": adopted,
                 "reach_requests": warm_reach,
                 "hit_rate": round(sum(warm_hits) / len(warm_hits), 3),
                 "elapsed_s": round(warm_s, 3),
                 "promotions": tiers["promotions"]},
    }
    ok = (cold_reach is not None and warm_reach is not None
          and warm_reach <= 0.2 * cold_reach)
    res["warm_reach_fraction"] = (round(warm_reach / cold_reach, 3)
                                  if cold_reach and warm_reach else None)
    res["meets_20pct_criterion"] = bool(ok)
    return res


# ------------------------------------------------------- cost-aware vs LRU


def policy_ab_experiment(wl, queries, stream, budget_frac: float) -> dict:
    from repro.core import SemanticCache
    from repro.core.sql_canon import SQLCanonicalizer
    from repro.olap.executor import OlapExecutor

    canon = SQLCanonicalizer(wl.schema)
    backend = OlapExecutor(wl.dataset, impl="numpy")
    sigs = [canon.canonicalize(q) for q in queries]
    tables, cost_ms = {}, {}
    for s in sigs:
        t0 = time.perf_counter()
        tables[s.key()] = backend.execute(s)
        cost_ms[s.key()] = (time.perf_counter() - t0) * 1e3
    footprint = sum(t.nbytes() for t in tables.values())
    budget = int(footprint * budget_frac)

    def replay(policy: str) -> dict:
        cache = SemanticCache(wl.schema, capacity_bytes=budget, policy=policy,
                              level_mapper=wl.dataset.level_mapper())
        hit_bytes = miss_cost = 0.0
        hits = 0
        for qi in stream:
            sig = sigs[qi]
            lr = cache.lookup(sig)
            if lr.status == "miss":
                miss_cost += cost_ms[sig.key()]
                cache.put(sig, tables[sig.key()], cost_ms=cost_ms[sig.key()])
            else:
                hits += 1
                hit_bytes += lr.table.nbytes()
        return {"policy": policy,
                "hit_rate": round(hits / len(stream), 3),
                "hit_bytes_served": int(hit_bytes),
                "recompute_ms_paid": round(miss_cost, 1),
                "evictions": cache.stats.evictions}

    lru, cost = replay("lru"), replay("cost")
    return {
        "population": len(queries),
        "requests": len(stream),
        "footprint_bytes": int(footprint),
        "capacity_bytes": budget,
        "lru": lru,
        "cost": cost,
        "hit_bytes_ratio": round(cost["hit_bytes_served"]
                                 / max(lru["hit_bytes_served"], 1), 3),
        "cost_beats_lru_on_hit_bytes": bool(
            cost["hit_bytes_served"] > lru["hit_bytes_served"]),
    }


# ---------------------------------------------------------------- drivers


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=40_000, help="SSB fact rows")
    ap.add_argument("--population", type=int, default=30,
                    help="distinct queries in the Zipf population")
    ap.add_argument("--requests", type=int, default=1_500,
                    help="Zipfian stream length")
    ap.add_argument("--budget-frac", type=float, default=0.3,
                    help="capacity_bytes as a fraction of the population "
                         "footprint (policy A/B)")
    ap.add_argument("--out", default="BENCH_store.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 6k rows, 400 requests")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.requests, args.population = 6_000, 400, 24

    from repro.workloads import ssb

    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    wl = ssb.build(n_fact=args.rows, seed=0)
    queries = build_population(args.population)
    stream = zipf_stream(len(queries), args.requests, seed=17)

    root = tempfile.mkdtemp(prefix="bench_store_")
    try:
        print("warm restart: cold stream -> kill -> reopen ...", flush=True)
        warm = warm_restart_experiment(wl, queries, stream, root)
        print(f"  steady-state hit rate {warm['steady_state_hit_rate']}, "
              f"cold reach {warm['cold']['reach_requests']} reqs, "
              f"warm reach {warm['warm']['reach_requests']} reqs "
              f"({warm['warm_reach_fraction']} of cold; "
              f"{'meets' if warm['meets_20pct_criterion'] else 'below'} "
              "the 20% criterion)")

        print("policy A/B: cost-aware vs LRU under byte pressure ...",
              flush=True)
        ab = policy_ab_experiment(wl, queries, stream, args.budget_frac)
        print(f"  lru  hit rate {ab['lru']['hit_rate']}, "
              f"{ab['lru']['hit_bytes_served']:,} hit bytes")
        print(f"  cost hit rate {ab['cost']['hit_rate']}, "
              f"{ab['cost']['hit_bytes_served']:,} hit bytes "
              f"({ab['hit_bytes_ratio']}x; "
              f"{'cost wins' if ab['cost_beats_lru_on_hit_bytes'] else 'LRU wins'})")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    report = {
        "config": {"rows": args.rows, "population": args.population,
                   "requests": args.requests,
                   "budget_frac": args.budget_frac, "quick": args.quick},
        "warm_restart": warm,
        "policy_ab": ab,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if not warm["meets_20pct_criterion"]:
        raise SystemExit("warm restart missed the 20% time-to-hit criterion")
    if not ab["cost_beats_lru_on_hit_bytes"]:
        raise SystemExit("cost-aware policy did not beat LRU on hit bytes")


if __name__ == "__main__":
    main()
