"""Backend throughput benchmark: seed per-measure path vs the fused
device-resident engine.

Runs a dashboard-style multi-measure query set over SSB (default 1M fact
rows), measures per-query latency (p50/p95) and aggregate scan throughput
(fact rows/sec) for

* ``legacy`` — the seed baseline: host numpy masks/expressions, one seg_agg
  launch per measure, per-query re-upload (``OlapExecutor(fused=False)``);
* ``fused``  — device-resident columns, on-device predicate masks, single
  fused SUM/COUNT/AVG launch (+1 for MIN/MAX) per query;
* ``batch``  — ``execute_batch`` refreshing the whole dashboard with one
  shared scan per (levels, measures) shape.

Writes ``BENCH_backend.json`` and cross-checks every fused/batch result
against the independent numpy oracle (fp32 reduction tolerance).

    PYTHONPATH=src python benchmarks/bench_backend.py            # 1M rows
    PYTHONPATH=src python benchmarks/bench_backend.py --quick    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

_JOINS = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
          "JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
          "JOIN part ON lineorder.lo_partkey = part.p_key ")

# A dashboard refresh: same measure block + grouping, sliced different ways,
# plus a couple of distinct shapes (the realistic mixed case).
_DASHBOARD = [
    f"SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, COUNT(*) AS n "
    f"FROM lineorder {_JOINS}WHERE d_year = {y} GROUP BY c_region"
    for y in (1992, 1993, 1994, 1995, 1996, 1997)
] + [
    f"SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, COUNT(*) AS n "
    f"FROM lineorder {_JOINS}WHERE c_region IN ('ASIA', 'EUROPE') GROUP BY c_region",
    f"SELECT c_nation, SUM(lo_revenue) AS rev, SUM(lo_extendedprice * lo_discount) AS disc, "
    f"COUNT(*) AS n, AVG(lo_supplycost) AS cost FROM lineorder {_JOINS}"
    f"WHERE lo_quantity < 30 AND d_year = 1994 GROUP BY c_nation",
    f"SELECT p_mfgr, SUM(lo_revenue) AS rev, MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
    f"FROM lineorder {_JOINS}WHERE s_region = 'AMERICA' GROUP BY p_mfgr",
]


def _percentiles(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "mean_ms": float(np.mean(a))}


def _run_path(executor, sigs, reps: int) -> dict:
    lat = []
    for _ in range(reps):
        for sig in sigs:
            t0 = time.perf_counter()
            executor.execute(sig)
            lat.append(time.perf_counter() - t0)
    total = sum(lat)
    n_rows = executor.ds.fact.num_rows
    return {**_percentiles(lat),
            "queries": len(lat),
            "total_s": total,
            "rows_per_sec": n_rows * len(lat) / total}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1_000_000, help="SSB fact rows")
    ap.add_argument("--reps", type=int, default=5, help="timed passes over the query set")
    ap.add_argument("--impl", default=None, help="seg_agg impl (default: kernel dispatch)")
    ap.add_argument("--out", default="BENCH_backend.json")
    ap.add_argument("--quick", action="store_true", help="CI smoke: 50k rows, 2 reps")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.reps = 50_000, 2

    from repro.core.sql_canon import SQLCanonicalizer
    from repro.kernels.seg_agg.ops import kernel_impl
    from repro.olap.executor import OlapExecutor
    from repro.workloads import ssb

    impl = args.impl or kernel_impl()
    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    t0 = time.perf_counter()
    wl = ssb.build(n_fact=args.rows, seed=0)
    print(f"  built in {time.perf_counter() - t0:.1f}s")
    canon = SQLCanonicalizer(wl.schema)
    sigs = [canon.canonicalize(q) for q in _DASHBOARD]

    legacy = OlapExecutor(wl.dataset, impl=impl, fused=False)
    fused = OlapExecutor(wl.dataset, impl=impl, fused=True)

    print("warmup (jit compile + device upload) ...", flush=True)
    for sig in sigs:
        legacy.execute(sig)
        fused.execute(sig)
    fused.execute_batch(sigs)

    print(f"timing legacy per-measure path ({args.reps} reps x {len(sigs)} queries) ...", flush=True)
    res_legacy = _run_path(legacy, sigs, args.reps)
    print(f"timing fused device-resident path ...", flush=True)
    res_fused = _run_path(fused, sigs, args.reps)

    print("timing execute_batch (dashboard refresh) ...", flush=True)
    lat = []
    for _ in range(args.reps):
        t0 = time.perf_counter()
        fused.execute_batch(sigs)
        lat.append(time.perf_counter() - t0)
    res_batch = {**_percentiles(lat),
                 "refreshes": len(lat),
                 "queries_per_refresh": len(sigs),
                 "rows_per_sec": wl.dataset.fact.num_rows * len(sigs) * len(lat) / sum(lat)}

    print("cross-checking fused + batch vs numpy oracle ...", flush=True)
    oracle = OlapExecutor(wl.dataset, impl="numpy")
    batch_tables = fused.execute_batch(sigs)
    mismatches = []
    for sig, bt in zip(sigs, batch_tables):
        expect = oracle.execute(sig)
        # fp32 reduction tolerance: the fused path accumulates in f32
        if not fused.execute(sig).equals(expect, rtol=1e-3):
            mismatches.append(("fused", sig.canonical_json()))
        if not bt.equals(expect, rtol=1e-3):
            mismatches.append(("batch", sig.canonical_json()))
    if mismatches:
        raise SystemExit(f"correctness check FAILED: {mismatches[:3]}")

    speedup = res_fused["rows_per_sec"] / res_legacy["rows_per_sec"]
    batch_speedup = res_batch["rows_per_sec"] / res_legacy["rows_per_sec"]
    report = {
        "workload": "ssb",
        "fact_rows": wl.dataset.fact.num_rows,
        "queries": len(sigs),
        "reps": args.reps,
        "impl": impl,
        "device_upload_ms": wl.dataset.upload_time_ms(),
        "legacy_per_measure": res_legacy,
        "fused_device_resident": res_fused,
        "batch_shared_scan": res_batch,
        "fused_speedup": speedup,
        "batch_speedup": batch_speedup,
        "oracle_checked": True,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"\n## backend throughput — SSB @ {wl.dataset.fact.num_rows:,} rows, impl={impl}")
    print(f"| path | rows/sec | p50 ms | p95 ms |")
    print(f"|---|---|---|---|")
    for name, r in (("legacy per-measure", res_legacy),
                    ("fused device-resident", res_fused),
                    ("batch shared-scan", res_batch)):
        print(f"| {name} | {r['rows_per_sec']:.3g} | {r['p50_ms']:.2f} | {r['p95_ms']:.2f} |")
    print(f"\nfused speedup: {speedup:.2f}x   batch speedup: {batch_speedup:.2f}x")
    print(f"wrote {args.out}")
    if speedup < 3 and not args.quick:
        print("WARNING: fused speedup below the 3x acceptance bar", file=sys.stderr)


if __name__ == "__main__":
    main()
