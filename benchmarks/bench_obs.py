"""Observability-plane benchmark: overhead and trace completeness.

Two experiments:

* **Warm-hit overhead** — the same warm-hit stream runs under four arms:
  observability fully off (the control), metrics-only (the production
  default: exposition mirrors existing counters, nothing on the hot path),
  full tracing at the default head-based sample rate (1%, the gated arm),
  and the whole plane (tracing + the cache audit log, informational).
  Arms are interleaved rep-by-rep so drift hits them all equally, and the
  headline is the min across per-rep p50s (like ``timeit``: arms differ
  only in code, so noise can only inflate a rep — the lowest one is the
  best estimate of intrinsic cost).  Acceptance: full tracing costs <= 5%
  on warm-hit p50 vs obs-off.

* **Trace completeness** — with every request sampled (rate 1.0), a mixed
  cold/warm/derivation stream over a sharded cluster with a durable store
  and a partition-parallel backend must produce, for every result, a span
  for every pipeline stage its provenance proves it passed through
  (:func:`repro.obs.trace_completeness`) — once clean, and once under an
  injected fault plan (backend errors + latency + spill faults).
  Acceptance: zero missing spans in both runs.

Writes ``BENCH_obs.json``.

    PYTHONPATH=src python benchmarks/bench_obs.py           # full run
    PYTHONPATH=src python benchmarks/bench_obs.py --quick   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import statistics
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")
GROUPS = ("c_region", "c_nation", "c_city")
MEASURES = ("SUM(lo_revenue) AS rev",
            "SUM(lo_revenue) AS rev, COUNT(*) AS n",
            "MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi")
YEARS = (1992, 1993, 1994, 1995)

CHAOS_PLAN = ("backend.error:0.15:11,backend.latency:0.05:13,"
              "storage.spill_error:0.2:17,canonicalize.timeout:0.05:19")


def build_population(n: int) -> list:
    grid = [f"SELECT {g}, {m} FROM lineorder {JOINS}"
            f"WHERE d_year = {y} GROUP BY {g}"
            for y in YEARS for g in GROUPS for m in MEASURES]
    return grid[:n]


# ------------------------------------------------------- warm-hit overhead


def make_service(wl, obs_cfg):
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService

    svc = CacheService(obs=obs_cfg)
    svc.register_tenant(
        "t", schema=wl.schema,
        backend=OlapExecutor(wl.dataset, impl="numpy"))
    return svc


def overhead_experiment(wl, queries, requests: int, reps: int) -> dict:
    from repro.obs import ObsConfig
    from repro.service import QueryRequest

    arms = {
        "off": ObsConfig.disabled(),
        "metrics": ObsConfig(),  # the production default
        "tracing": ObsConfig(tracing=True),  # + tracing at default 1%
        "full_plane": ObsConfig.full(),  # + the audit log as well
    }
    services = {}
    for name, cfg in arms.items():
        svc = make_service(wl, cfg)
        for q in queries:  # warm: every query resident before measuring
            svc.submit(QueryRequest(sql=q, tenant="t"))
        services[name] = svc

    stream = [queries[i % len(queries)] for i in range(requests)]
    p50s: dict[str, list[float]] = {name: [] for name in arms}
    qps: dict[str, list[float]] = {name: [] for name in arms}
    for rep in range(reps):
        # interleave arms within each rep so machine drift (thermal, noisy
        # neighbours) hits all three equally
        for name, svc in services.items():
            lat = []
            t0 = time.perf_counter()
            for q in stream:
                t1 = time.perf_counter()
                r = svc.submit(QueryRequest(sql=q, tenant="t"))
                lat.append((time.perf_counter() - t1) * 1e3)
                assert r.status == "hit_exact", r.status
            wall = time.perf_counter() - t0
            p50s[name].append(float(np.percentile(lat, 50)))
            qps[name].append(len(stream) / wall)
    out: dict = {"arms": {}}
    for name in arms:
        out["arms"][name] = {
            # the gated headline is min-of-reps: like timeit, the lowest
            # rep is the least-noise estimate of intrinsic cost (the arms
            # only differ by code, so noise can only inflate a rep)
            "p50_ms": round(min(p50s[name]), 5),
            "p50_ms_median": round(statistics.median(p50s[name]), 5),
            "p50_ms_reps": [round(v, 5) for v in p50s[name]],
            "qps": round(statistics.median(qps[name]), 1),
        }
    base = out["arms"]["off"]["p50_ms"]
    for name in ("metrics", "tracing", "full_plane"):
        d = out["arms"][name]
        d["overhead_pct_p50"] = round(100.0 * (d["p50_ms"] - base)
                                      / base, 2) if base else 0.0
    fp = services["full_plane"]
    out["tracer"] = fp.obs.tracer.stats()
    out["audit"] = fp.obs.audit.stats()
    # the hard gate is the ISSUE's criterion: *full tracing* at default
    # sampling <= 5% over obs-off (the audit log is its own layer; its
    # all-on cost is reported above as the full_plane arm)
    out["meets_5pct_criterion"] = bool(
        out["arms"]["tracing"]["overhead_pct_p50"] <= 5.0)
    return out


# ------------------------------------------------------ trace completeness


def completeness_run(wl, queries, requests: int, chaos: bool) -> dict:
    from repro.obs import ObsConfig, trace_completeness
    from repro.olap.executor import OlapExecutor
    from repro.resilience import faults
    from repro.service import CacheService, QueryRequest

    root = tempfile.mkdtemp(prefix="bench_obs_")
    svc = CacheService(obs=ObsConfig.full(sample_rate=1.0))
    try:
        svc.register_tenant(
            "t", schema=wl.schema,
            backend=OlapExecutor(wl.dataset, impl="numpy", partitions=2),
            shards=2)
        svc.open(root)
        results = []
        rng = np.random.default_rng(29)

        def drive():
            # mixed batches: cold misses, warm hits, in-batch duplicates
            i = 0
            while len(results) < requests:
                size = int(rng.integers(1, 5))
                batch = [QueryRequest(sql=queries[(i + j) % len(queries)],
                                      tenant="t")
                         for j in range(size)]
                i += max(size - 1, 1)  # overlap: duplicates across batches
                results.extend(svc.submit_batch(batch))

        if chaos:
            with faults.scoped(CHAOS_PLAN):
                drive()
        else:
            drive()
        comp = trace_completeness(results, svc.obs.tracer)
        statuses: dict[str, int] = {}
        for r in results:
            statuses[r.status] = statuses.get(r.status, 0) + 1
        return {
            "chaos": chaos,
            "requests": len(results),
            "statuses": statuses,
            "traces_checked": comp["traces_checked"],
            "missing_spans": comp["missing_count"],
            "missing_detail": comp["missing"][:5],
            "spans_emitted": svc.obs.tracer.stats()["spans_emitted"],
            "audit_events": svc.obs.audit.stats()["emitted"],
            "ok": comp["ok"],
        }
    finally:
        svc.close()
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------- driver


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=40_000, help="SSB fact rows")
    ap.add_argument("--population", type=int, default=18,
                    help="distinct warm queries")
    ap.add_argument("--requests", type=int, default=2_000,
                    help="warm-hit requests per rep per arm")
    ap.add_argument("--reps", type=int, default=7,
                    help="interleaved measurement reps")
    ap.add_argument("--completeness-requests", type=int, default=300)
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 6k rows, shorter streams")
    args = ap.parse_args()
    if args.quick:
        # plenty of reps even in quick mode: the gate compares sub-us p50
        # deltas, and min-of-reps only shakes off noise if enough reps land
        # on a quiet machine
        args.rows, args.requests, args.reps = 6_000, 1_000, 9
        args.completeness_requests = 150

    from repro.workloads import ssb

    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    wl = ssb.build(n_fact=args.rows, seed=0)
    queries = build_population(args.population)

    print(f"warm-hit overhead: {args.reps} reps x {args.requests} requests "
          f"x 4 arms ...", flush=True)
    ovh = overhead_experiment(wl, queries, args.requests, args.reps)
    for name, d in ovh["arms"].items():
        extra = (f", overhead {d['overhead_pct_p50']:+.2f}%"
                 if "overhead_pct_p50" in d else "")
        print(f"  {name:>10}: p50 {d['p50_ms']:.4f} ms, "
              f"{d['qps']:,.0f} qps{extra}", flush=True)

    print("trace completeness: clean run ...", flush=True)
    clean = completeness_run(wl, queries, args.completeness_requests,
                             chaos=False)
    print(f"  {clean['traces_checked']} traces checked, "
          f"{clean['missing_spans']} missing spans, "
          f"{clean['spans_emitted']} spans emitted", flush=True)
    print("trace completeness: chaos run ...", flush=True)
    chaos = completeness_run(wl, queries, args.completeness_requests,
                             chaos=True)
    print(f"  {chaos['traces_checked']} traces checked, "
          f"{chaos['missing_spans']} missing spans, statuses "
          f"{chaos['statuses']}", flush=True)

    report = {
        "config": {"rows": args.rows, "population": args.population,
                   "requests": args.requests, "reps": args.reps,
                   "completeness_requests": args.completeness_requests,
                   "quick": args.quick},
        "overhead": ovh,
        "completeness": {"clean": clean, "chaos": chaos,
                         "zero_missing": bool(clean["ok"] and chaos["ok"])},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if not report["completeness"]["zero_missing"]:
        raise SystemExit("trace completeness violated: missing stage spans")
    if not ovh["meets_5pct_criterion"]:
        raise SystemExit(
            f"full-tracing warm-hit p50 overhead was "
            f"{ovh['arms']['tracing']['overhead_pct_p50']:+.2f}% (> +5%)")


if __name__ == "__main__":
    main()
