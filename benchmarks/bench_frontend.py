"""Request-plane front-end benchmark: warm-template traffic vs cold parse.

The paper's headline scenario is the *hit path*: 82% of the evaluation
corpus is served from cache, so once misses are fast (PRs 1-3) the
canonicalize -> hash -> lookup front end dominates end-to-end latency.  This
benchmark drives mixed SQL/NL dashboard traffic at ~100% hit rate through
``CacheService`` twice:

* ``fast``     — the request-plane fast path: parameterized template cache
  (tokenize + two dict probes per re-arrival), interned signature keys,
  memoized validation, indexed derivation probes;
* ``baseline`` — the cold-parse path: template cache and validation memo
  disabled, every arrival pays full parse -> canonicalize -> validate.
  (Signatures are still interned per instance, so this baseline is slightly
  *faster* than the true pre-fast-path code, which hashed 3-4x per request —
  the reported speedup is conservative.)

Every fast-path response table is cross-checked against the cold-path
response for the same request (oracle check; any mismatch exits non-zero).
Reports hit-path p50/p99 latency and QPS per surface, plus the template
cache and derivation-probe counters, and writes ``BENCH_frontend.json``.

    PYTHONPATH=src python benchmarks/bench_frontend.py           # 60k rows
    PYTHONPATH=src python benchmarks/bench_frontend.py --quick   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

_JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")

# Parameterized dashboard tiles: {y}/{r}/{q}/{a}/{b} are the literal slots a
# template cache rebinds; each (template, binding) pair is a distinct intent.
SQL_TEMPLATES = [
    ("SELECT c_region, SUM(lo_revenue) AS rev, COUNT(*) AS n "
     "FROM lineorder {j}WHERE d_year = {y} GROUP BY c_region"),
    ("SELECT c_nation, SUM(lo_revenue) AS rev, MIN(lo_supplycost) AS lo, "
     "MAX(lo_supplycost) AS hi FROM lineorder {j}"
     "WHERE c_region = '{r}' AND d_year = {y} GROUP BY c_nation"),
    ("SELECT c_region, AVG(lo_quantity) AS q FROM lineorder {j}"
     "WHERE lo_discount BETWEEN {a} AND {b} GROUP BY c_region"),
    ("SELECT c_city, COUNT(*) AS n FROM lineorder {j}"
     "WHERE c_nation = '{n}' AND lo_quantity < {q} GROUP BY c_city"),
    ("SELECT d_year, SUM(lo_revenue) AS rev FROM lineorder {j}"
     "WHERE lo_quantity < {q} GROUP BY d_year"),
    ("SELECT c_region, SUM(lo_extendedprice) AS gross FROM lineorder {j}"
     "WHERE lo_date >= '{d0}' AND lo_date < '{d1}' GROUP BY c_region"),
]

NL_TEXTS = [
    "total revenue by customer region in {y}",
    "total revenue by customer nation in {y}",
    "how many orders by customer region in {y}",
]


def build_stream(seed: int = 0) -> tuple[list, list]:
    """(sql_texts, nl_texts): the distinct warm-template working set."""
    rng = random.Random(seed)
    regions = ["ASIA", "EUROPE", "AMERICA", "AFRICA"]
    nations = ["ASIA_0", "EUROPE_1", "AMERICA_2"]
    sql = []
    for y in range(1992, 1998):
        sql.append(SQL_TEMPLATES[0].format(j=_JOINS, y=y))
        sql.append(SQL_TEMPLATES[1].format(j=_JOINS, r=rng.choice(regions), y=y))
    for a, b in ((1, 3), (2, 5), (4, 6)):
        sql.append(SQL_TEMPLATES[2].format(j=_JOINS, a=a, b=b))
    for n in nations:
        sql.append(SQL_TEMPLATES[3].format(j=_JOINS, n=n, q=rng.randint(10, 40)))
    for q in (10, 25, 40):
        sql.append(SQL_TEMPLATES[4].format(j=_JOINS, q=q))
    for d0, d1 in (("1992-01-01", "1993-01-01"), ("1994-06-01", "1995-06-01")):
        sql.append(SQL_TEMPLATES[5].format(j=_JOINS, d0=d0, d1=d1))
    nl = [t.format(y=y) for t in NL_TEXTS for y in (1993, 1995)]
    return sql, nl


def _service(wl, backend, fast: bool):
    from repro.core import MemoizedNL, SemanticCache, SimulatedLLM
    from repro.core.sql_canon import SQLCanonicalizer
    from repro.core.validator import SignatureValidator
    from repro.service import CacheService

    from repro.core import SafetyPolicy

    svc = CacheService()
    # gating is out of scope here (the oracle model never errs); aggressive
    # policy keeps repeated NL on the cache path instead of per-rep bypass
    t = svc.register_tenant(
        "dash", schema=wl.schema, backend=backend,
        nl=MemoizedNL(SimulatedLLM(wl.vocab, model="oracle")),
        policy=SafetyPolicy.aggressive(),
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(),
                            indexed_probes=fast))
    if not fast:
        # cold-parse baseline: no template cache, no validation memo
        t.sql_canon = SQLCanonicalizer(wl.schema, template_cache=False)
        t.validator = SignatureValidator(wl.schema, memo_capacity=0)
    return svc, t


def _lat_stats(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(np.mean(a)), "n": len(lat_s)}


def run_path(svc, requests, reps: int, seed: int) -> tuple[dict, dict]:
    """Warm once (misses execute + store), then time ``reps`` shuffled passes
    of pure hit traffic.  Returns latency/QPS per surface + responses for the
    oracle cross-check."""
    warm = svc.submit_batch(requests)
    n_miss = sum(r.status == "miss" for r in warm)
    rng = random.Random(seed)
    lat = {"sql": [], "nl": []}
    responses = {}
    order = list(range(len(requests)))
    t_all0 = time.perf_counter()
    for _ in range(reps):
        rng.shuffle(order)
        for i in order:
            req = requests[i]
            t0 = time.perf_counter()
            r = svc.submit(req)
            lat[req.kind].append(time.perf_counter() - t0)
            responses[i] = r
    wall_s = time.perf_counter() - t_all0
    hits = sum(1 for r in responses.values() if r.hit)
    n_timed = sum(len(v) for v in lat.values())
    out = {
        "warm_misses": n_miss,
        "distinct_requests": len(requests),
        "timed_requests": n_timed,
        "hit_rate_timed": hits / max(1, len(responses)),
        "wall_s": wall_s,
        "qps": n_timed / wall_s,
        "sql": _lat_stats(lat["sql"]),
        "nl": _lat_stats(lat["nl"]) if lat["nl"] else None,
        "sql_qps": len(lat["sql"]) / sum(lat["sql"]),
    }
    return out, responses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=60_000, help="SSB fact rows")
    ap.add_argument("--reps", type=int, default=30,
                    help="timed shuffled passes over the working set")
    ap.add_argument("--out", default="BENCH_frontend.json")
    ap.add_argument("--quick", action="store_true", help="CI smoke: 20k rows, 8 reps")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.reps = 20_000, 8

    from repro.olap.executor import OlapExecutor
    from repro.service import QueryRequest
    from repro.workloads import ssb

    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    wl = ssb.build(n_fact=args.rows, seed=0)
    backend = OlapExecutor(wl.dataset, impl="numpy")

    sql, nl = build_stream()
    requests = ([QueryRequest(sql=q, tenant="dash") for q in sql]
                + [QueryRequest(nl=t, tenant="dash") for t in nl])
    print(f"working set: {len(sql)} SQL intents over {len(SQL_TEMPLATES)} "
          f"templates + {len(nl)} NL texts; {args.reps} timed passes")

    svc_fast, ten_fast = _service(wl, backend, fast=True)
    fast, resp_fast = run_path(svc_fast, requests, args.reps, seed=1)
    svc_cold, ten_cold = _service(wl, backend, fast=False)
    cold, resp_cold = run_path(svc_cold, requests, args.reps, seed=1)

    # oracle: every fast-path response table equals the cold-path table
    mismatches = 0
    for i in resp_fast:
        a, b = resp_fast[i], resp_cold[i]
        if (a.table is None) != (b.table is None) or a.status != b.status:
            mismatches += 1
        elif a.table is not None and not a.table.equals(
                b.table, ordered=bool(a.signature and a.signature.order_by)):
            mismatches += 1
    if mismatches:
        raise SystemExit(f"ORACLE MISMATCH: {mismatches} fast-path responses "
                         "differ from the cold path")

    speedup_sql = fast["sql_qps"] / cold["sql_qps"]
    report = {
        "workload": "ssb", "rows": args.rows, "reps": args.reps,
        "fast": fast, "baseline": cold,
        "speedup_sql_qps": speedup_sql,
        "speedup_sql_p50": cold["sql"]["p50_ms"] / fast["sql"]["p50_ms"],
        "speedup_overall_qps": fast["qps"] / cold["qps"],
        "oracle_ok": True,
        "frontend_stats": svc_fast.stats("dash")["frontend"],
        "derivation_counters": {
            "candidates_scanned":
                ten_fast.cache.stats.derivation_candidates_scanned,
            "plans_attempted": ten_fast.cache.stats.derivation_plans_attempted,
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nSQL hit path   p50 {fast['sql']['p50_ms']:.3f} ms (cold "
          f"{cold['sql']['p50_ms']:.3f}), p99 {fast['sql']['p99_ms']:.3f} ms "
          f"(cold {cold['sql']['p99_ms']:.3f})")
    if fast["nl"]:
        print(f"NL hit path    p50 {fast['nl']['p50_ms']:.3f} ms (cold "
              f"{cold['nl']['p50_ms']:.3f})")
    print(f"SQL hit QPS    {fast['sql_qps']:.0f} vs cold {cold['sql_qps']:.0f} "
          f"-> {speedup_sql:.1f}x")
    print(f"overall QPS    {fast['qps']:.0f} vs cold {cold['qps']:.0f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
