"""Service-layer benchmark: single-request vs batched dashboard refresh.

A 12-tile dashboard (shared grouping + measure block, differing filters and
time windows) refreshes against a cold cache through the batch-first
``CacheService``:

* ``serial``  — one ``submit()`` per tile: every miss pays its own
  canonicalize -> lookup -> execute round trip (the pre-service request
  path, one fused backend execution per tile);
* ``batched`` — one ``submit_batch()`` for the whole refresh: the miss
  planner dedups in-flight intents and routes all misses through
  ``OlapExecutor.execute_batch`` — one shared scan and a single fused
  ``seg_agg_batch_blocks`` launch (SUM + MIN/MAX blocks together) for the
  entire dashboard.

Reports per-request p50/p95, refresh wall time, and backend *launch counts*
(the seg_agg dispatcher probe), cross-checks batched tables against the
independent numpy oracle, and writes ``BENCH_service.json``.

    PYTHONPATH=src python benchmarks/bench_service.py            # 500k rows
    PYTHONPATH=src python benchmarks/bench_service.py --quick    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

_JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")
_BASE = ("SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, "
         "COUNT(*) AS n, MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
         f"FROM lineorder {_JOINS}")

# 12 tiles: shared grouping + measures, differing filters/time windows
DASHBOARD = (
    [_BASE + f"WHERE d_year = {y} GROUP BY c_region"
     for y in (1992, 1993, 1994, 1995, 1996, 1997)]
    + [_BASE + f"WHERE lo_date >= '{a}' AND lo_date < '{b}' GROUP BY c_region"
       for a, b in (("1992-01-01", "1992-07-01"), ("1993-02-01", "1994-02-01"),
                    ("1995-06-01", "1996-06-01"))]
    + [_BASE + f"WHERE lo_quantity {op} GROUP BY c_region"
       for op in ("< 10", "< 25", "> 40")]
)


def _percentiles(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "mean_ms": float(np.mean(a))}


def _fresh_service(wl, backend):
    from repro.core import SemanticCache
    from repro.service import CacheService

    svc = CacheService()
    tenant = svc.register_tenant(
        "dash", schema=wl.schema, backend=backend,
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper()))
    return svc, tenant


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=500_000, help="SSB fact rows")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed cold-cache refreshes per path")
    ap.add_argument("--impl", default=None, help="seg_agg impl (default: kernel dispatch)")
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--quick", action="store_true", help="CI smoke: 30k rows, 2 reps")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.reps = 30_000, 2

    from repro.kernels.seg_agg.ops import (kernel_impl, launch_count,
                                           reset_launch_count)
    from repro.olap.executor import OlapExecutor
    from repro.service import QueryRequest
    from repro.workloads import ssb

    impl = args.impl or kernel_impl()
    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    t0 = time.perf_counter()
    wl = ssb.build(n_fact=args.rows, seed=0)
    print(f"  built in {time.perf_counter() - t0:.1f}s")

    backend = OlapExecutor(wl.dataset, impl=impl, fused=True)
    reqs = [QueryRequest(sql=q, tenant="dash") for q in DASHBOARD]

    # correctness first: batched-served misses must equal the numpy oracle
    print("oracle cross-check (batched vs independent numpy path) ...", flush=True)
    svc, _ = _fresh_service(wl, backend)
    results = svc.submit_batch(reqs)
    oracle = OlapExecutor(wl.dataset, impl="numpy")
    for r in results:
        direct = oracle.execute(r.signature)
        if not r.table.equals(direct, ordered=bool(r.signature.order_by)):
            raise SystemExit(f"MISMATCH vs oracle for {r.signature.key()[:12]}")
    print(f"  ok ({len(results)} tiles, all served via "
          f"{'batch' if all(x.batched for x in results) else 'mixed'} execution)")

    # warmup: jit compile + device upload (shared by both paths)
    svc, _ = _fresh_service(wl, backend)
    for r in reqs:
        svc.submit(r)

    print(f"timing serial refresh ({args.reps} cold-cache reps x "
          f"{len(reqs)} tiles) ...", flush=True)
    serial_lat, serial_refresh, serial_launches = [], [], []
    for _ in range(args.reps):
        svc, _ = _fresh_service(wl, backend)
        reset_launch_count()
        t0 = time.perf_counter()
        for r in reqs:
            t1 = time.perf_counter()
            svc.submit(r)
            serial_lat.append(time.perf_counter() - t1)
        serial_refresh.append(time.perf_counter() - t0)
        serial_launches.append(launch_count())

    print("timing batched refresh (submit_batch) ...", flush=True)
    batch_refresh, batch_launches, batch_stats = [], [], None
    for _ in range(args.reps):
        svc, tenant = _fresh_service(wl, backend)
        reset_launch_count()
        t0 = time.perf_counter()
        svc.submit_batch(reqs)
        batch_refresh.append(time.perf_counter() - t0)
        batch_launches.append(launch_count())
        batch_stats = tenant.stats.to_dict()

    n = len(reqs)
    serial_total = float(np.mean(serial_refresh))
    batch_total = float(np.mean(batch_refresh))
    report = {
        "rows": args.rows,
        "tiles": n,
        "impl": impl,
        "reps": args.reps,
        "serial": {**_percentiles(serial_lat),
                   "refresh_ms": serial_total * 1e3,
                   "launches_per_refresh": float(np.mean(serial_launches))},
        "batched": {**_percentiles([t / n for t in batch_refresh]),
                    "refresh_ms": batch_total * 1e3,
                    "launches_per_refresh": float(np.mean(batch_launches))},
        "speedup_refresh": serial_total / batch_total if batch_total else 0.0,
        "service_stats_last_batched_refresh": batch_stats,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: report[k] for k in
                      ("serial", "batched", "speedup_refresh")}, indent=2))
    print(f"wrote {args.out}: {n}-tile refresh "
          f"{serial_total * 1e3:.1f}ms serial -> {batch_total * 1e3:.1f}ms batched "
          f"({report['speedup_refresh']:.1f}x), launches "
          f"{np.mean(serial_launches):.0f} -> {np.mean(batch_launches):.0f}")


if __name__ == "__main__":
    main()
