"""Cluster benchmark: multi-threaded closed-loop load vs shard count.

Two parts:

* **Differential oracle** — a mixed SQL/NL workload with duplicate-in-batch
  requests, roll-up derivation probes, and incremental snapshot advances runs
  single-threaded through ``shards=1`` and ``shards=4`` services; every
  request's (status, result table) and the refresh report must be identical.
  Family partitioning by ``(scope, schema, measure_key)`` keeps derivation
  candidates shard-local, so sharding may never change an outcome.

* **Closed-loop hit-path QPS** — T worker threads hammer a warm
  ``CacheCluster`` with exact-hit lookups over a multi-scope signature
  population (scopes spread derivation families across shards).  The
  single-shard cluster is the *locked* baseline: every thread contends on
  one lock, so a GIL preemption inside the critical section convoys every
  other worker.  With N shards only threads targeting the preempted shard
  stall.  Reports aggregate QPS and per-op p50/p95 per shard count and the
  4-shard/1-shard speedup (acceptance: >= 2x at 8 threads).

Writes ``BENCH_cluster.json``.

    PYTHONPATH=src python benchmarks/bench_cluster.py           # full run
    PYTHONPATH=src python benchmarks/bench_cluster.py --quick   # CI smoke
"""
from __future__ import annotations

import argparse
import datetime as dt
import json
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")

# measure blocks define derivation families; scopes multiply them so the
# population spreads over shards
MEASURE_BLOCKS = (
    "SUM(lo_revenue) AS rev",
    "SUM(lo_revenue) AS rev, COUNT(*) AS n",
    "MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi",
    "SUM(lo_extendedprice) AS ep",
    "COUNT(*) AS n",
    "SUM(lo_quantity) AS q, SUM(lo_revenue) AS rev",
)


def build_population(schema, scopes: int) -> list:
    """Distinct warm signatures: measure-block x scope x year grid."""
    from repro.core.sql_canon import SQLCanonicalizer

    canon = SQLCanonicalizer(schema)
    sigs = []
    for sc in range(scopes):
        for mb in MEASURE_BLOCKS:
            for year in (1992, 1993, 1994, 1995):
                sql = (f"SELECT c_region, {mb} FROM lineorder {JOINS}"
                       f"WHERE d_year = {year} GROUP BY c_region")
                sigs.append(canon.canonicalize(sql, scope=f"tenant-{sc}"))
    return sigs


# ------------------------------------------------------------------ oracle


def run_oracle_trace(rows: int, shards: int) -> list:
    """One deterministic mixed workload through a fresh service; returns the
    outcome trace (statuses + tables + refresh report) for differencing.
    Builds its own workload copy — the snapshot advance appends delta rows to
    the dataset, so runs must not share one."""
    from benchmarks.bench_refresh import make_delta
    from repro.core import MemoizedNL, SemanticCache, SimulatedLLM
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService, QueryRequest
    from repro.workloads import ssb

    wl = ssb.build(n_fact=rows, seed=0)
    backend = OlapExecutor(wl.dataset, impl="numpy")
    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema, backend=backend,
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper()),
        nl=MemoizedNL(SimulatedLLM(wl.vocab, model="oracle")),
        shards=shards)

    base = f"SELECT c_region, SUM(lo_revenue) AS rev, COUNT(*) AS n FROM lineorder {JOINS}"
    sqls = [base + f"WHERE d_year = {y} GROUP BY c_region"
            for y in (1992, 1993, 1994)]
    # finer grouping first, so the coarser request later derives via roll-up
    fine = base + "WHERE d_year = 1995 GROUP BY c_region, c_nation"
    coarse = base + "WHERE d_year = 1995 GROUP BY c_region"
    nls = ["total revenue by region", "number of orders"]

    def record(trace, results):
        for r in results:
            if r.table is None:
                trace.append((r.status, None))
                continue
            # row order is unspecified for ORDER-BY-free queries (execute vs
            # execute_batch may decode groups differently) — compare as a
            # sorted row set, keyed by the full row
            names = r.table.names
            rows = sorted(zip(*[map(str, r.table.columns[n]) for n in names]))
            ordered = bool(r.signature.order_by) if r.signature else False
            trace.append((r.status, names,
                          [tuple(map(str, r.table.columns[n])) for n in names]
                          if ordered else rows))

    trace: list = []
    record(trace, svc.submit_batch(
        [QueryRequest(sql=q, tenant="t") for q in sqls + [fine, sqls[0]]]))
    record(trace, svc.submit_batch(
        [QueryRequest(sql=coarse, tenant="t")]
        + [QueryRequest(nl=x, tenant="t", now=dt.date(1995, 6, 1)) for x in nls]))
    rep = svc.advance_snapshot(
        "t", "snap1", delta=make_delta(wl.dataset, 200, np.random.default_rng(7)))
    trace.append(("refresh", rep.refreshed, rep.recomputed, rep.dropped,
                  rep.unaffected, rep.updated_start, rep.updated_end))
    record(trace, svc.submit_batch(
        [QueryRequest(sql=q, tenant="t") for q in sqls + [coarse]]))
    return trace


# ---------------------------------------------------------------- hit path


SWITCH_INTERVAL_S = 5e-4  # thread preemption quantum during the closed loop


def closed_loop(cluster, sigs, n_threads: int, duration_s: float) -> dict:
    """Closed-loop load: each thread cycles its own shuffled view of the warm
    signature population issuing exact-hit lookups until the deadline.

    The loop pins ``sys.setswitchinterval`` to a 0.5 ms quantum — applied
    identically to every shard count — so thread preemption (and therefore
    lock-convoy behavior, the phenomenon under test) is frequent enough to be
    reproducible within a short measurement window; the CPython default of
    5 ms makes single-lock convoys a long-lived bimodal regime and the
    baseline numbers noisy.  Real cache servers live in the preemption-heavy
    end: I/O threads, timers, and followers waking from flights all force
    switches far more often than pure compute loops do."""
    counts = [0] * n_threads
    samples: list[list[float]] = [[] for _ in range(n_threads)]
    errors: list[BaseException] = []
    barrier = threading.Barrier(n_threads + 1)
    stop = threading.Event()

    def worker(tid: int) -> None:
        rng = np.random.default_rng(tid)
        order = rng.permutation(len(sigs))
        my = [sigs[i] for i in order]
        lookup = cluster.lookup
        barrier.wait()
        n = 0
        sample = samples[tid]
        perf = time.perf_counter
        try:
            while not stop.is_set():
                sig = my[n % len(my)]
                t0 = perf()
                lr = lookup(sig)
                t1 = perf()
                if lr.status != "hit_exact":  # must stay on the hit path
                    raise RuntimeError(f"unexpected {lr.status} in warm loop")
                if n % 64 == 0:
                    sample.append(t1 - t0)
                n += 1
        except BaseException as e:  # a dead worker must fail the run, not
            errors.append(e)        # silently skew the reported QPS
            raise
        counts[tid] = n

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n_threads)]
    prev_interval = sys.getswitchinterval()
    sys.setswitchinterval(SWITCH_INTERVAL_S)
    try:
        for t in threads:
            t.start()
        barrier.wait()
        t0 = time.perf_counter()
        time.sleep(duration_s)
        stop.set()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
    finally:
        sys.setswitchinterval(prev_interval)
    if errors:
        raise SystemExit(f"closed-loop worker failed: {errors[0]!r}")
    lat = np.asarray(sorted(x for s in samples for x in s)) * 1e6
    total = sum(counts)
    return {
        "threads": n_threads,
        "duration_s": round(elapsed, 3),
        "lookups": total,
        "qps": round(total / elapsed, 1),
        "p50_us": round(float(np.percentile(lat, 50)), 2),
        "p95_us": round(float(np.percentile(lat, 95)), 2),
        "per_thread_qps": [round(c / elapsed, 1) for c in counts],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=60_000, help="SSB fact rows")
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--scopes", type=int, default=24,
                    help="scope count (spreads families over shards)")
    ap.add_argument("--duration", type=float, default=1.5,
                    help="seconds per closed-loop rep")
    ap.add_argument("--reps", type=int, default=3,
                    help="closed-loop reps per shard count (median reported)")
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--out", default="BENCH_cluster.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 8k rows, 1s x 2 reps, shards 1+4")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.duration, args.reps = 8_000, 1.0, 2
        args.shards = [1, 4]

    from repro.cluster import CacheCluster
    from repro.olap.executor import OlapExecutor
    from repro.workloads import ssb

    # -- differential oracle: sharded outcomes must equal single-shard ones
    print("differential oracle: shards=4 vs shards=1 mixed workload ...",
          flush=True)
    trace1 = run_oracle_trace(args.rows, shards=1)
    trace4 = run_oracle_trace(args.rows, shards=4)
    if trace1 != trace4:
        for i, (a, b) in enumerate(zip(trace1, trace4)):
            if a != b:
                raise SystemExit(f"ORACLE MISMATCH at checkpoint {i}: "
                                 f"{a[0]} != {b[0]}")
        raise SystemExit("ORACLE MISMATCH: trace lengths differ")
    print(f"  identical ({len(trace1)} checkpoints: hits, misses, "
          "derivations, refresh report)")

    # -- warm signature population, served once by the real backend
    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    wl = ssb.build(n_fact=args.rows, seed=0)
    sigs = build_population(wl.schema, args.scopes)
    backend = OlapExecutor(wl.dataset, impl="numpy")
    tables = {s.key(): backend.execute(s) for s in sigs}
    print(f"population: {len(sigs)} signatures "
          f"({args.scopes} scopes x {len(MEASURE_BLOCKS)} measure blocks x 4 years)")

    # -- closed-loop hit path per shard count (median of --reps runs)
    hit_path: dict[str, dict] = {}
    for n in args.shards:
        cluster = CacheCluster(wl.schema, shards=n,
                               level_mapper=wl.dataset.level_mapper())
        for s in sigs:
            cluster.put(s, tables[s.key()])
        spread = [len(sh) for sh in cluster.shards()]
        runs = [closed_loop(cluster, sigs, args.threads, args.duration)
                for _ in range(args.reps)]
        res = sorted(runs, key=lambda r: r["qps"])[len(runs) // 2]
        res["shard_entries"] = spread
        res["qps_reps"] = [r["qps"] for r in runs]
        hit_path[str(n)] = res
        print(f"  shards={n}: {res['qps']:>10,.0f} lookups/s   "
              f"p50 {res['p50_us']:.1f}us  p95 {res['p95_us']:.1f}us  "
              f"spread {spread}  reps {res['qps_reps']}")

    report = {
        "config": {"rows": args.rows, "threads": args.threads,
                   "scopes": args.scopes, "duration_s": args.duration,
                   "reps": args.reps,
                   "switch_interval_s": SWITCH_INTERVAL_S,
                   "population": len(sigs), "quick": args.quick},
        "oracle": {"checkpoints": len(trace1), "identical": True},
        "hit_path": hit_path,
    }
    if "1" in hit_path and "4" in hit_path:
        speedup = hit_path["4"]["qps"] / hit_path["1"]["qps"]
        report["speedup_4shard_vs_1shard"] = round(speedup, 2)
        report["meets_2x_criterion"] = bool(speedup >= 2.0)
        print(f"4-shard vs single-shard locked path: {speedup:.2f}x "
              f"({'meets' if speedup >= 2.0 else 'below'} the 2x criterion)")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
