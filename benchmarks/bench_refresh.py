"""Incremental snapshot refresh benchmark: delta merge vs drop-and-recompute.

A dashboard of composable intents (SUM/COUNT/MIN/MAX over shared grouping,
differing filters; one closed window inside the delta's date range, one
safely outside) is warmed against a cold cache, then the fact table receives
append-only deltas ("ticks").  Two identically seeded service instances
handle each tick:

* ``incremental`` — ``advance_snapshot(delta=...)``: append, scan *only the
  delta partition* as one fused batch, and merge the delta aggregates into
  the cached tables (``core.refresh``);
* ``recompute``   — ``advance_snapshot(delta=..., refresh=False)`` followed
  by re-warming the dashboard: append, drop affected entries, and pay full
  scans to rebuild them (the pre-incremental behavior).

Reports per-tick wall time (first tick separated: it carries the delta-shape
jit compile), fact rows scanned per tick, and the refresh-vs-recompute
speedup; cross-checks the incrementally maintained tables against an
independent numpy-oracle full recompute over the grown dataset, and writes
``BENCH_refresh.json``.  Target (ISSUE 3): >=5x at 1M base rows / 10k delta.

    PYTHONPATH=src python benchmarks/bench_refresh.py            # 1M rows
    PYTHONPATH=src python benchmarks/bench_refresh.py --quick    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

_JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
          "JOIN part ON lineorder.lo_partkey = part.p_key "
          "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")
_BASE = ("SELECT {lvl}, SUM(lo_revenue) AS rev, COUNT(*) AS n, "
         "MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
         f"FROM lineorder {_JOINS}")

# Composable dashboard: windowless tiles are affected by every tick; the
# d_year tiles show the window-intersection rule (1998 refreshes, 1992 stays
# untouched because the deltas only carry 1998 dates).
DASHBOARD = (
    [_BASE.format(lvl="c_region") + w + "GROUP BY c_region"
     for w in ("", "WHERE lo_quantity < 25 ", "WHERE lo_discount <= 3 ",
               "WHERE c_region = 'ASIA' ", "WHERE p_mfgr = 'MFGR#1' ",
               "WHERE d_year = 1998 ", "WHERE d_year = 1992 ")]
    + [_BASE.format(lvl=lvl) + f"GROUP BY {lvl}"
       for lvl in ("c_nation", "s_region", "d_year")]
)


def make_delta(ds, n: int, rng, year: int = 1998) -> dict:
    """Append-batch of fact rows shaped like ssb.build_dataset's generator,
    with order dates confined to ``year`` (so the derived update extent
    exercises the window-intersection rule)."""
    dim = ds.dims["dates"]
    day_keys = np.nonzero(dim.columns["d_year"].data == year)[0]
    od = rng.choice(day_keys, size=n)
    qty = rng.integers(1, 51, size=n)
    price = np.round(rng.uniform(100, 10_000, size=n), 2)
    disc = rng.integers(0, 11, size=n)
    return {
        "lo_orderdate": od,
        "lo_custkey": rng.integers(0, ds.dims["customer"].num_rows, size=n),
        "lo_suppkey": rng.integers(0, ds.dims["supplier"].num_rows, size=n),
        "lo_partkey": rng.integers(0, ds.dims["part"].num_rows, size=n),
        "lo_quantity": qty,
        "lo_extendedprice": price,
        "lo_discount": disc,
        "lo_revenue": np.round(price * (1 - disc / 100.0), 2),
        "lo_supplycost": np.round(price * rng.uniform(0.4, 0.8, size=n), 2),
        "lo_date": dim.columns["d_date"].data[od],
    }


def _setup(args, impl, name):
    from repro.core import SemanticCache
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService
    from repro.workloads import ssb

    wl = ssb.build(n_fact=args.rows, seed=0)
    backend = OlapExecutor(wl.dataset, impl=impl, fused=True)
    svc = CacheService()
    svc.register_tenant(name, schema=wl.schema, backend=backend,
                        cache=SemanticCache(wl.schema,
                                            level_mapper=wl.dataset.level_mapper()))
    return wl, backend, svc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1_000_000, help="SSB fact rows")
    ap.add_argument("--delta", type=int, default=10_000, help="rows appended per tick")
    ap.add_argument("--ticks", type=int, default=4, help="append ticks to time")
    ap.add_argument("--impl", default=None, help="seg_agg impl (default: kernel dispatch)")
    ap.add_argument("--out", default="BENCH_refresh.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 60k rows, 2k deltas, 3 ticks")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.delta, args.ticks = 60_000, 2_000, 3
    if args.ticks < 2:
        raise SystemExit("--ticks must be >= 2 (tick 1 carries jit compiles)")

    from repro.kernels.seg_agg.ops import kernel_impl
    from repro.olap.executor import OlapExecutor
    from repro.service import QueryRequest

    impl = args.impl or kernel_impl()
    print(f"building 2x SSB ({args.rows:,} fact rows, impl={impl}) ...", flush=True)
    t0 = time.perf_counter()
    wl_inc, be_inc, svc_inc = _setup(args, impl, "inc")
    wl_rec, be_rec, svc_rec = _setup(args, impl, "rec")
    print(f"  built in {time.perf_counter() - t0:.1f}s")

    reqs_inc = [QueryRequest(sql=q, tenant="inc") for q in DASHBOARD]
    reqs_rec = [QueryRequest(sql=q, tenant="rec") for q in DASHBOARD]
    print(f"warming {len(DASHBOARD)}-tile dashboard on both services ...", flush=True)
    svc_inc.submit_batch(reqs_inc)
    svc_rec.submit_batch(reqs_rec)

    rng = np.random.default_rng(7)
    inc_ms, rec_ms, inc_rows, rec_rows, reports = [], [], [], [], []
    print(f"running {args.ticks} append ticks of {args.delta:,} rows ...", flush=True)
    for tick in range(args.ticks):
        delta = make_delta(wl_inc.dataset, args.delta, rng)

        r0 = be_inc.rows_scanned
        t0 = time.perf_counter()
        rep = svc_inc.advance_snapshot("inc", f"snap{tick + 1}", delta=delta)
        inc_ms.append((time.perf_counter() - t0) * 1e3)
        inc_rows.append(be_inc.rows_scanned - r0)
        reports.append(rep.to_dict())

        r0 = be_rec.rows_scanned
        t0 = time.perf_counter()
        svc_rec.advance_snapshot("rec", f"snap{tick + 1}", delta=delta,
                                 refresh=False)
        svc_rec.submit_batch(reqs_rec)  # dropped tiles rebuild via full scans
        rec_ms.append((time.perf_counter() - t0) * 1e3)
        rec_rows.append(be_rec.rows_scanned - r0)
        print(f"  tick {tick + 1}: incremental {inc_ms[-1]:.1f}ms "
              f"({inc_rows[-1]:,} rows scanned) vs recompute {rec_ms[-1]:.1f}ms "
              f"({rec_rows[-1]:,} rows)", flush=True)

    # oracle: incrementally maintained tables == full recompute on grown data
    print("oracle cross-check (merged tables vs numpy full rescan) ...", flush=True)
    oracle = OlapExecutor(wl_inc.dataset, impl="numpy")
    served = svc_inc.submit_batch(
        [QueryRequest(sql=q, tenant="inc", read_only=True) for q in DASHBOARD])
    for r in served:
        if not r.hit:
            raise SystemExit(f"tile not served from cache after refresh: {r.status}")
        if not r.table.equals(oracle.execute(r.signature)):
            raise SystemExit(
                f"MISMATCH vs oracle for {r.signature.key()[:12]} "
                f"(served@{r.source_snapshot})")
    print(f"  ok ({len(served)} tiles, all cache hits after {args.ticks} ticks)")

    warm_inc = float(np.mean(inc_ms[1:]))
    warm_rec = float(np.mean(rec_ms[1:]))
    report = {
        "rows": args.rows,
        "delta_rows": args.delta,
        "ticks": args.ticks,
        "tiles": len(DASHBOARD),
        "impl": impl,
        "incremental": {"tick_ms": inc_ms, "warm_mean_ms": warm_inc,
                        "first_tick_ms": inc_ms[0],
                        "rows_scanned_per_tick": inc_rows},
        "recompute": {"tick_ms": rec_ms, "warm_mean_ms": warm_rec,
                      "first_tick_ms": rec_ms[0],
                      "rows_scanned_per_tick": rec_rows},
        "speedup_warm": warm_rec / warm_inc if warm_inc else 0.0,
        "scan_ratio": (float(np.mean(rec_rows)) / float(np.mean(inc_rows))
                       if np.mean(inc_rows) else 0.0),
        "target_speedup": 5.0,
        "last_refresh_report": reports[-1],
    }
    report["target_met"] = report["speedup_warm"] >= report["target_speedup"]
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({k: report[k] for k in
                      ("incremental", "recompute", "speedup_warm", "scan_ratio")},
                     indent=2))
    print(f"wrote {args.out}: refresh {warm_inc:.1f}ms vs recompute "
          f"{warm_rec:.1f}ms per tick ({report['speedup_warm']:.1f}x, "
          f"target >=5x {'MET' if report['target_met'] else 'not met'}; "
          f"scans {np.mean(inc_rows):,.0f} vs {np.mean(rec_rows):,.0f} rows)")


if __name__ == "__main__":
    main()
