"""Scan-plane throughput benchmark: partition-parallel fact scans with
merge-combine, plus a streaming run beyond the device-memory budget.

Runs a dashboard-style query set over SSB (default 1M fact rows) through
``OlapExecutor(partitions=p)`` for p in 1/2/4/8 and measures steady-state
cache-miss scan throughput (fact rows/sec, post warmup so jit compile and
device upload are excluded).  ``partitions=1`` is the unpartitioned oracle:
every merged result is cross-checked against it (fp32 reduction tolerance).

A second phase builds a dataset larger than ``--max-device-rows`` (default
10M rows vs a 2M-row budget) and runs the same queries through the
double-buffered streaming chunk scan, verifying it completes and matches
the single-upload oracle.

    PYTHONPATH=src python benchmarks/bench_scan.py            # 1M + 10M rows
    PYTHONPATH=src python benchmarks/bench_scan.py --quick    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

_JOINS = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
          "JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
          "JOIN part ON lineorder.lo_partkey = part.p_key ")

# A cache-miss burst: shared measure block sliced different ways plus two
# distinct shapes, exercising SUM/COUNT/AVG merge and the MIN/MAX combiner.
_MISSES = [
    f"SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, COUNT(*) AS n "
    f"FROM lineorder {_JOINS}WHERE d_year = {y} GROUP BY c_region"
    for y in (1993, 1995, 1997)
] + [
    f"SELECT c_nation, SUM(lo_revenue) AS rev, SUM(lo_extendedprice * lo_discount) AS disc, "
    f"COUNT(*) AS n FROM lineorder {_JOINS}"
    f"WHERE lo_quantity < 30 AND d_year = 1994 GROUP BY c_nation",
    f"SELECT p_mfgr, SUM(lo_revenue) AS rev, MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
    f"FROM lineorder {_JOINS}WHERE s_region = 'AMERICA' GROUP BY p_mfgr",
]


def _percentiles(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "mean_ms": float(np.mean(a))}


def _time_batch(executor, sigs, reps: int) -> dict:
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        executor.execute_batch(sigs)
        lat.append(time.perf_counter() - t0)
    n_rows = executor.ds.fact.num_rows
    return {**_percentiles(lat),
            "refreshes": len(lat),
            "queries_per_refresh": len(sigs),
            "total_s": sum(lat),
            "rows_per_sec": n_rows * len(sigs) * len(lat) / sum(lat)}


def _check(tables, oracle_tables, sigs, label: str) -> None:
    mismatches = []
    for sig, got, expect in zip(sigs, tables, oracle_tables):
        # fp32 reduction tolerance: per-partition partials accumulate in f32
        if not got.equals(expect, rtol=1e-3):
            mismatches.append((label, sig.canonical_json()))
    if mismatches:
        raise SystemExit(f"correctness check FAILED: {mismatches[:3]}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1_000_000, help="SSB fact rows (scaling phase)")
    ap.add_argument("--reps", type=int, default=5, help="timed passes over the query set")
    ap.add_argument("--partitions", default="1,2,4,8", help="comma-separated partition counts")
    ap.add_argument("--stream-rows", type=int, default=10_000_000,
                    help="SSB fact rows for the streaming phase")
    ap.add_argument("--max-device-rows", type=int, default=2_000_000,
                    help="device row budget for the streaming phase")
    ap.add_argument("--impl", default=None, help="seg_agg impl (default: kernel dispatch)")
    ap.add_argument("--out", default="BENCH_scan.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 60k rows, 2 reps, 200k-row stream vs 32k budget")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.reps = 60_000, 2
        args.stream_rows, args.max_device_rows = 200_000, 32_768
    plist = [int(p) for p in args.partitions.split(",")]

    from repro.core.sql_canon import SQLCanonicalizer
    from repro.kernels.seg_agg.ops import kernel_impl
    from repro.olap.executor import OlapExecutor
    from repro.workloads import ssb

    impl = args.impl or kernel_impl()
    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    t0 = time.perf_counter()
    wl = ssb.build(n_fact=args.rows, seed=0)
    print(f"  built in {time.perf_counter() - t0:.1f}s")
    canon = SQLCanonicalizer(wl.schema)
    sigs = [canon.canonicalize(q) for q in _MISSES]

    # --- scaling curve: partitions = 1, 2, 4, 8 over the same dataset -----
    results: dict[str, dict] = {}
    oracle_tables = None
    for p in plist:
        ex = OlapExecutor(wl.dataset, impl=impl, fused=True, partitions=p)
        print(f"warmup partitions={p} (jit compile + device upload) ...", flush=True)
        tables = ex.execute_batch(sigs)
        if p == 1:
            oracle_tables = tables
        print(f"timing partitions={p} ({args.reps} reps x {len(sigs)} queries) ...", flush=True)
        r = _time_batch(ex, sigs, args.reps)
        st = ex.stats()
        r["partitioned_scans"] = st["partitioned_scans"]
        r["per_partition_rows"] = [ps["rows_scanned"] for ps in st["per_partition"]]
        results[str(p)] = r
        if p != 1 and oracle_tables is not None:
            _check(ex.execute_batch(sigs), oracle_tables, sigs, f"partitions={p}")

    base = results[str(plist[0])]["rows_per_sec"]
    for p in plist:
        results[str(p)]["speedup_vs_1"] = results[str(p)]["rows_per_sec"] / base

    # --- streaming: dataset larger than the device row budget -------------
    print(f"\nbuilding SSB: {args.stream_rows:,} fact rows (streaming phase) ...", flush=True)
    t0 = time.perf_counter()
    swl = ssb.build(n_fact=args.stream_rows, seed=1)
    print(f"  built in {time.perf_counter() - t0:.1f}s")
    ssigs = [canon.canonicalize(q) for q in _MISSES[:2]]
    stream = OlapExecutor(swl.dataset, impl=impl, fused=True,
                          partitions=2, max_device_rows=args.max_device_rows)
    print(f"streaming scan: {args.stream_rows:,} rows through a "
          f"{args.max_device_rows:,}-row device budget ...", flush=True)
    t0 = time.perf_counter()
    stream_tables = stream.execute_batch(ssigs)
    stream_s = time.perf_counter() - t0
    sstats = stream.stats()
    print("cross-checking streaming result vs single-upload oracle ...", flush=True)
    soracle = OlapExecutor(swl.dataset, impl=impl, fused=True)
    _check(stream_tables, soracle.execute_batch(ssigs), ssigs, "streaming")
    res_stream = {
        "fact_rows": swl.dataset.fact.num_rows,
        "max_device_rows": args.max_device_rows,
        "partitions": 2,
        "streaming_chunks": sstats["streaming_chunks"],
        "cold_total_s": stream_s,
        "rows_per_sec": swl.dataset.fact.num_rows * len(ssigs) / stream_s,
        "completed": True,
    }

    speedup4 = None
    if "4" in results and "1" in results:
        speedup4 = results["4"]["rows_per_sec"] / results["1"]["rows_per_sec"]
    try:
        n_cpus = len(os.sched_getaffinity(0))
    except AttributeError:
        n_cpus = os.cpu_count() or 1
    report = {
        "workload": "ssb",
        "fact_rows": wl.dataset.fact.num_rows,
        "queries": len(sigs),
        "reps": args.reps,
        "impl": impl,
        "cpus": n_cpus,
        "scaling": results,
        "speedup_4_partitions": speedup4,
        "streaming": res_stream,
        "oracle_checked": True,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"\n## scan plane — SSB @ {wl.dataset.fact.num_rows:,} rows, impl={impl}")
    print("| partitions | rows/sec | p50 ms | p95 ms | speedup |")
    print("|---|---|---|---|---|")
    for p in plist:
        r = results[str(p)]
        print(f"| {p} | {r['rows_per_sec']:.3g} | {r['p50_ms']:.2f} "
              f"| {r['p95_ms']:.2f} | {r['speedup_vs_1']:.2f}x |")
    print(f"\nstreaming: {res_stream['fact_rows']:,} rows / "
          f"{res_stream['max_device_rows']:,}-row budget -> "
          f"{res_stream['streaming_chunks']} chunks, "
          f"{res_stream['rows_per_sec']:.3g} rows/sec")
    print(f"wrote {args.out}")
    if speedup4 is not None and speedup4 < 2 and not args.quick:
        print(f"WARNING: 4-partition speedup {speedup4:.2f}x below the 2x "
              f"acceptance bar ({n_cpus} usable CPU(s): with one core the "
              f"partition pool cannot parallelize, only cache locality "
              f"remains; the bar presumes >=4 cores or devices)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
