"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Terms per (arch x shape x mesh):
  compute    = FLOPs_global / (chips x 197 TFLOP/s bf16)
  memory     = HBM bytes_global / (chips x 819 GB/s)
  collective = collective bytes (per-device module, while-trip-corrected)
               / 50 GB/s per ICI link

FLOPs/bytes come from the *unrolled* lowering (XLA's HloCostAnalysis counts
while bodies once; see launch/dryrun.py); bytes_global is pre-fusion and
therefore an upper bound on HBM traffic.  MODEL_FLOPS uses 6·N·D for training
(N_active for MoE), 2·N·D for prefill, 2·N·B for decode — the ratio to HLO
FLOPs exposes remat/dispatch-slack waste.
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

CHIPS = {"single": 256, "multi": 512}
TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
          "decode_32k": 128, "long_500k": 1}


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    d = TOKENS[rec["shape"]]
    if rec["shape"] == "train_4k":
        return 6.0 * n * d
    return 2.0 * n * d  # prefill: per prompt token; decode: per new token


def analyze(rec: dict) -> dict:
    chips = CHIPS[rec["mesh"]]
    fg = rec.get("flops_global") or 0.0
    bg = rec.get("bytes_global") or 0.0
    coll = rec["collectives"]["total_bytes"]
    compute_s = fg / (chips * PEAK_FLOPS)
    memory_s = bg / (chips * HBM_BW)
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    bound = max(terms.values())
    useful_frac = mf / fg if fg else 0.0
    # roofline fraction: useful-model-compute time over the bound term
    ideal_s = mf / (chips * PEAK_FLOPS)
    frac = ideal_s / bound if bound > 0 else 0.0
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": useful_frac,
        "roofline_fraction": frac,
        "step_bound_s": bound,
    }


_MOVES = {
    "compute": "reduce non-useful FLOPs (remat policy, MoE capacity slack, "
               "fused GLU) or grow per-chip batch to amortize",
    "memory": "cut HBM traffic: fuse elementwise chains, keep KV/state in "
              "bf16, larger kernel tiles so weights stream once",
    "collective": "reshard to shrink collective volume: sequence-sharded "
                  "residual (SP), intra-pod TP only, overlap reduce-scatter "
                  "with backward compute",
}


def rows(results: dict, mesh: str = "single"):
    out = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok" or rec["mesh"] != mesh:
            continue
        if len(key.split("|")) > 3:  # perf variants live in the §Perf table
            continue
        a = analyze(rec)
        out.append((rec, a))
    return out


def variants_table(results: dict):
    """§Perf: baseline vs hillclimbed variants for the three chosen cells."""
    lines = ["| cell | variant | collective GB | collective(s) | compute(s) | dominant |",
             "|---|---|---|---|---|---|"]
    for key, rec in sorted(results.items()):
        parts = key.split("|")
        if rec.get("status") != "ok" or len(parts) < 4:
            continue
        a = analyze(rec)
        coll_gb = rec["collectives"]["total_bytes"] / 1e9
        lines.append(f"| {parts[0]} {parts[1]} | {parts[3]} | {coll_gb:.3f} "
                     f"| {a['collective_s']:.2e} | {a['compute_s']:.2e} | {a['dominant']} |")
    return lines


def report(path: str = "results/dryrun.json", mesh: str = "single"):
    with open(path) as f:
        results = json.load(f)
    lines = [f"## Roofline ({mesh} pod = {CHIPS[mesh]} chips; 197 TF/s bf16, "
             "819 GB/s HBM, 50 GB/s/link)",
             "| arch | shape | compute(s) | memory(s) | collective(s) | dominant "
             "| MODEL_FLOPS/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    csv = []
    for rec, a in rows(results, mesh):
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {a['compute_s']:.2e} "
            f"| {a['memory_s']:.2e} | {a['collective_s']:.2e} | {a['dominant']} "
            f"| {a['useful_flops_ratio']:.2f} | {a['roofline_fraction']:.2f} |")
        csv.append((f"roofline_{rec['arch']}_{rec['shape']}_{mesh}",
                    a["step_bound_s"] * 1e6,
                    f"dom={a['dominant']},frac={a['roofline_fraction']:.2f}"))
    lines.append("")
    lines.append("Moves per dominant term: " + "; ".join(
        f"**{k}** -> {v}" for k, v in _MOVES.items()))
    return lines, csv


def main():
    lines, _ = report()
    print("\n".join(lines))


if __name__ == "__main__":
    main()
