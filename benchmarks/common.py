"""Shared benchmark infrastructure: baseline caches (TextCache, ASTCache,
NL-to-SQL+AST) and the evaluation runner with false-hit auditing."""
from __future__ import annotations

import dataclasses
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,  # noqa: E402
                        SimulatedLLM)
from repro.core import sqlparse as sp  # noqa: E402
from repro.service import CacheService, QueryRequest  # noqa: E402
from repro.core.signature import Signature  # noqa: E402
from repro.core.sql_canon import CanonicalizationError  # noqa: E402
from repro.olap.executor import OlapExecutor  # noqa: E402
from repro.workloads.render import Style, render  # noqa: E402
from repro.workloads.variants import rename_aliases  # noqa: E402

QUALIFIED = ("customer region", "supplier region", "customer city", "supplier city",
             "customer nation", "supplier nation", "pickup zone", "dropoff zone",
             "pickup borough", "dropoff borough")

N_FACT = int(os.environ.get("REPRO_BENCH_FACT_ROWS", "40000"))

_WL_CACHE: dict[str, object] = {}


def get_workload(name: str):
    if name not in _WL_CACHE:
        from repro.workloads import nyc_tlc, ssb, tpcds

        _WL_CACHE[name] = {"ssb": ssb, "nyc_tlc": nyc_tlc, "tpcds": tpcds}[name].build(
            n_fact=N_FACT)
    return _WL_CACHE[name]


# ------------------------------------------------------------------ keyings


def text_key(sql: str) -> str:
    """Normalized-text cache key (TextCache baseline)."""
    s = re.sub(r"--[^\n]*", " ", sql)
    s = re.sub(r"/\*.*?\*/", " ", s, flags=re.S)
    s = s.lower().replace(";", " ")
    return re.sub(r"\s+", " ", s).strip()


def ast_key(sql: str) -> str | None:
    """AST-canonical cache key (ASTCache baseline): positional aliases,
    sorted predicates/joins/group-by, fixed rendering style.  Does NOT unify
    time representations, BETWEEN<->inequalities, or commuted expressions —
    that is precisely the gap intent signatures close."""
    try:
        q = sp.parse(sql)
    except (sp.SQLSyntaxError, sp.UnsupportedQuery):
        return None
    q = rename_aliases(q, "tN")
    style = Style(upper_keywords=False, newlines=False)
    q = dataclasses.replace(
        q,
        joins=tuple(sorted(q.joins, key=lambda j: j.table)),
        where=tuple(sorted(q.where, key=lambda p: _pred_key(p, style))),
        group_by=tuple(sorted(q.group_by, key=lambda c: (c.table or "", c.column))),
    )
    # join order changes alias numbering; renormalize once more
    q = rename_aliases(q, "tN")
    return render(q, style)


def _pred_key(p, style):
    from repro.workloads.render import render_predicate

    return render_predicate(p, style)


def sql_from_signature(sig: Signature, schema) -> str:
    """Deterministic SQL rendering of a signature (the NL-to-SQL baseline's
    text-to-SQL stage)."""
    sel = []
    for i, m in enumerate(sig.measures):
        if m.agg == "COUNT_DISTINCT":
            sel.append(f"COUNT(DISTINCT {m.expr}) AS m{i}")
        elif m.expr == "*":
            sel.append(f"COUNT(*) AS m{i}")
        else:
            sel.append(f"{m.agg}({m.expr}) AS m{i}")
    sel = [*sig.levels, *sel]
    dims = sorted({ref.split(".")[0] for ref in sig.levels}
                  | {f.col.split(".")[0] for f in sig.filters}
                  | {t for m in sig.measures if m.expr != "*"
                     for t in _expr_tables(m.expr)})
    dims = [d for d in dims if d != schema.fact.name]
    joins = " ".join(
        f"JOIN {d} ON {schema.fact.name}.{schema.dimension(d).fact_fk} = "
        f"{d}.{schema.dimension(d).pk}" for d in sorted(dims))
    where = []
    for f in sig.filters:
        if isinstance(f.val, tuple):
            vals = ", ".join(_lit(v) for v in f.val)
            where.append(f"{f.col} in ({vals})")
        else:
            where.append(f"{f.col} {f.op} {_lit(f.val)}")
    if sig.time_window is not None and schema.fact.date_column:
        dc = f"{schema.fact.name}.{schema.fact.date_column}"
        where.append(f"{dc} >= '{sig.time_window.start}'")
        where.append(f"{dc} < '{sig.time_window.end}'")
    parts = [f"SELECT {', '.join(sel)}", f"FROM {schema.fact.name}", joins]
    if where:
        parts.append("WHERE " + " AND ".join(sorted(where)))
    if sig.levels:
        parts.append("GROUP BY " + ", ".join(sig.levels))
    for h in sig.having:
        m = sig.measures[h.measure]
        expr = "COUNT(*)" if m.expr == "*" else f"{m.agg}({m.expr})"
        parts.append(f"HAVING {expr} {h.op} {_lit(h.val)}")
    if sig.order_by:
        keys = []
        for o in sig.order_by:
            k = f"m{o.key.split(':')[1]}" if o.key.startswith("measure:") else o.key
            keys.append(k + (" DESC" if o.desc else ""))
        parts.append("ORDER BY " + ", ".join(keys))
    if sig.limit is not None:
        parts.append(f"LIMIT {sig.limit}")
    return " ".join(p for p in parts if p)


def _expr_tables(expr: str) -> set[str]:
    return set(re.findall(r"\b([a-z_][a-z0-9_]*)\.", expr))


def _lit(v) -> str:
    if isinstance(v, str):
        return "'" + v.replace("'", "''") + "'"
    if isinstance(v, float) and v == int(v):
        return str(int(v))
    return str(v)


# ------------------------------------------------------------------ methods


@dataclasses.dataclass
class MethodResult:
    method: str
    workload: str
    total: int = 0
    hits: int = 0
    misses: int = 0
    bypasses: int = 0
    false_hits: int = 0
    backend_execs: int = 0
    distinct_keys: int = 0
    sql_queries: int = 0
    lookup_ms: list = dataclasses.field(default_factory=list)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.total if self.total else 0.0

    @property
    def reduction(self) -> float:
        """Queries per cache key over the processed (SQL-capable) subset."""
        n = self.sql_queries or self.total
        return n / self.distinct_keys if self.distinct_keys else 0.0


class KeyedCache:
    """Hit-rate harness for key-based baselines."""

    def __init__(self):
        self.store: set[str] = set()

    def access(self, key: str | None) -> str:
        if key is None:
            return "bypass"
        if key in self.store:
            return "hit"
        self.store.add(key)
        return "miss"


def run_method(method: str, wl, queries, model: str = "gpt-4o-mini",
               audit_false_hits: bool = False) -> MethodResult:
    res = MethodResult(method, wl.name, total=len(queries))
    if method in ("text", "ast"):
        cache = KeyedCache()
        for q in queries:
            if q.kind != "sql":
                res.misses += 1  # SQL-only baselines cannot serve NL
                continue
            res.sql_queries += 1
            t0 = time.perf_counter()
            key = text_key(q.text) if method == "text" else ast_key(q.text)
            status = cache.access(key)
            res.lookup_ms.append((time.perf_counter() - t0) * 1e3)
            if status == "hit":
                res.hits += 1
            elif status == "miss":
                res.misses += 1
                res.backend_execs += 1
            else:
                res.bypasses += 1
                res.backend_execs += 1
        res.distinct_keys = len(cache.store)
        return res

    if method == "nl2sql_ast":
        cache = KeyedCache()
        llm = MemoizedNL(SimulatedLLM(wl.vocab, model=model))
        for q in queries:
            t0 = time.perf_counter()
            if q.kind == "sql":
                key = ast_key(q.text)
            else:
                r = llm.canonicalize(q.text)
                key = None
                if r.signature is not None:
                    try:
                        key = ast_key(sql_from_signature(r.signature, wl.schema))
                    except (CanonicalizationError, KeyError, AttributeError):
                        key = None
            status = cache.access(key)
            res.lookup_ms.append((time.perf_counter() - t0) * 1e3)
            res.sql_queries += 1
            if status == "hit":
                res.hits += 1
            elif status == "miss":
                res.misses += 1
                res.backend_execs += 1
            else:
                res.bypasses += 1
                res.backend_execs += 1
        res.distinct_keys = len(cache.store)
        return res

    # ---- llmsig: the full pipeline, through the batch-first service API
    backend = OlapExecutor(wl.dataset, impl="numpy")
    oracle = OlapExecutor(wl.dataset, impl="numpy") if audit_false_hits else None
    cache = SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper())
    llm = MemoizedNL(SimulatedLLM(wl.vocab, model=model))
    svc = CacheService()
    svc.register_tenant(
        "bench", schema=wl.schema, backend=backend, cache=cache, nl=llm,
        policy=SafetyPolicy.balanced(wl.spatial_ambiguous, qualified=QUALIFIED))
    for q in queries:
        req = (QueryRequest(sql=q.text, tenant="bench") if q.kind == "sql"
               else QueryRequest(nl=q.text, tenant="bench"))
        r = svc.submit(req)
        t = r.timings_ms
        res.lookup_ms.append(t.get("lookup", 0.0) + t.get("canonicalize", 0.0)
                             + t.get("validate", 0.0))
        res.sql_queries += 1
        if r.hit:
            res.hits += 1
            if oracle is not None:
                direct = oracle.execute(r.signature)
                if not r.table.equals(direct, ordered=bool(r.signature.order_by)):
                    res.false_hits += 1
        elif r.status == "miss":
            res.misses += 1
        else:
            res.bypasses += 1
    res.backend_execs = backend.executions
    res.distinct_keys = len(cache)
    return res


def med_p95(values):
    if not values:
        return 0.0, 0.0
    v = sorted(values)
    return v[len(v) // 2], v[min(len(v) - 1, int(len(v) * 0.95))]
