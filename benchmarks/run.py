"""Benchmark harness — one function per paper table.  Prints the markdown
report to stdout and ``name,us_per_call,derived`` CSV lines at the end.

``--quick`` runs a CI smoke subset on a tiny dataset (set before any
workload import so REPRO_BENCH_FACT_ROWS takes effect).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    if quick:
        os.environ.setdefault("REPRO_BENCH_FACT_ROWS", "2000")

    from benchmarks import roofline, tables

    sections = [
        ("table1", tables.table1_hitrate),
        ("table2", tables.table2_adversarial),
        ("table3", tables.table3_safety),
        ("table4", tables.table4_overhead),
        ("table5", tables.table5_profiles),
        ("rq4", tables.rq4_derivations),
        ("birdlike", tables.birdlike_eval),
        ("perf_trend", tables.perf_trend),
    ]
    if quick:
        sections = [("table1", tables.table1_hitrate),
                    ("perf_trend", tables.perf_trend)]
    all_csv = []
    for name, fn in sections:
        t0 = time.perf_counter()
        lines, csv = fn()
        dt = time.perf_counter() - t0
        print("\n".join(lines))
        print(f"\n[{name} completed in {dt:.1f}s]\n")
        all_csv.extend(csv)

    if os.path.exists("results/dryrun.json"):
        for mesh in ("single", "multi"):
            lines, csv = roofline.report("results/dryrun.json", mesh=mesh)
            print("\n".join(lines))
            print()
            all_csv.extend(csv)
        import json

        with open("results/dryrun.json") as f:
            res = json.load(f)
        print("## §Perf — measured sharding variants (see EXPERIMENTS.md §Perf)")
        print("\n".join(roofline.variants_table(res)))
        print()
    else:
        print("(results/dryrun.json missing — run `python -m repro.launch.dryrun --all`)")

    print("\nname,us_per_call,derived")
    for name, us, derived in all_csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
