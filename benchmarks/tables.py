"""Paper-table benchmarks (one function per table/figure).

Every function returns (markdown_lines, csv_rows) where csv rows follow
``name,us_per_call,derived``.
"""
from __future__ import annotations

import datetime as _dt
import time

from .common import (QUALIFIED, MethodResult, get_workload, med_p95, run_method)

METHODS = [("TextCache", "text"), ("ASTCache", "ast"),
           ("NL-to-SQL+AST", "nl2sql_ast"), ("LLMSigCache", "llmsig")]
WORKLOADS = ["nyc_tlc", "ssb", "tpcds"]


# --------------------------------------------------------------- Table 1


def table1_hitrate():
    lines = ["## Table 1 — Cache performance by method",
             "| Method | NYC TLC | SSB | TPC-DS | Avg | Red.NYC | Red.SSB | Red.DS |",
             "|---|---|---|---|---|---|---|---|"]
    csv = []
    results: dict[tuple, MethodResult] = {}
    for disp, method in METHODS:
        rates, reds = [], []
        t0 = time.perf_counter()
        for wname in WORKLOADS:
            wl = get_workload(wname)
            queries = wl.queries(order="sequential")
            r = run_method(method, wl, queries, audit_false_hits=(method == "llmsig"))
            results[(method, wname)] = r
            rates.append(r.hit_rate)
            reds.append(r.reduction)
        dt_us = (time.perf_counter() - t0) * 1e6
        avg = sum(rates) / len(rates)
        lines.append(
            f"| {disp} | {rates[0]*100:.1f} | {rates[1]*100:.1f} | {rates[2]*100:.1f} "
            f"| {avg*100:.1f} | {reds[0]:.1f}x | {reds[1]:.1f}x | {reds[2]:.1f}x |")
        csv.append((f"table1_{method}", dt_us, f"avg_hit={avg*100:.1f}%"))
    fh = sum(results[("llmsig", w)].false_hits for w in WORKLOADS)
    total_exec = {m: sum(results[(m, w)].backend_execs for w in WORKLOADS)
                  for _, m in METHODS}
    total_q = sum(results[("llmsig", w)].total for w in WORKLOADS)
    savings = 1 - total_exec["llmsig"] / total_q
    lines.append("")
    lines.append(f"False hits (LLMSigCache, audited per query): **{fh}**  |  "
                 f"backend-compute saving: **{savings*100:.1f}%** "
                 f"({total_exec['llmsig']} executions / {total_q} queries; "
                 f"paper: 85-90%)")
    csv.append(("table1_false_hits", 0.0, str(fh)))
    csv.append(("table1_backend_saving", 0.0, f"{savings*100:.1f}%"))
    return lines, csv


# --------------------------------------------------------------- Table 2


def _adversarial_results(model: str):
    from repro.core import SimulatedLLM
    from repro.workloads import adversarial

    qs = adversarial.build()
    vocabs = {w: get_workload(w).vocab for w in WORKLOADS}
    llms = {k: SimulatedLLM(v, model=model) for k, v in vocabs.items()}
    res = [llms[q.schema].canonicalize(q.text, now=None) for q in qs]
    return qs, res


def table2_adversarial():
    from repro.workloads import adversarial

    t0 = time.perf_counter()
    qs, res = _adversarial_results("gpt-4o-mini")
    sc = adversarial.score(qs, res)
    dt_us = (time.perf_counter() - t0) * 1e6
    order = ["metric", "time", "dimension", "aggregation", "compositional"]
    lines = ["## Table 2 — Semantic accuracy on 63 adversarial NL queries",
             "| Ambiguity type | N | Correct | Wrong | Invalid |", "|---|---|---|---|---|"]
    tot = {"correct": 0, "wrong": 0, "invalid": 0}
    for t in order:
        b = sc["per_type"][t]
        n = sum(b.values())
        lines.append(f"| {t} | {n} | {b['correct']} | {b['wrong']} | {b['invalid']} |")
        for k in tot:
            tot[k] += b[k]
    lines.append(f"| **Total** | 63 | {tot['correct']} | {tot['wrong']} | {tot['invalid']} |")
    acc = tot["correct"] / 63
    lines.append(f"\nAccuracy {acc*100:.1f}% (paper: 44.4%)")
    return lines, [("table2_accuracy", dt_us, f"{acc*100:.1f}%")]


# --------------------------------------------------------------- Table 3


def table3_safety():
    from repro.core.safety import SafetyPolicy, gate_nl
    from repro.workloads import adversarial

    qs, res = _adversarial_results("gpt-4o-mini")
    t0 = time.perf_counter()
    lines = ["## Table 3a — Confidence threshold: coverage vs precision",
             "| Threshold | Coverage | Precision |", "|---|---|---|"]
    csv = []
    for thr in (0.3, 0.5, 0.7, 0.9):
        accepted = correct = 0
        for q, r in zip(qs, res):
            if r.signature is None or r.confidence < thr:
                continue
            accepted += 1
            if q.gold is not None and r.signature.key() == q.gold.key():
                correct += 1
        cov = accepted / len(qs)
        prec = correct / accepted if accepted else 0.0
        lines.append(f"| {thr} | {cov*100:.1f}% | {prec*100:.1f}% |")
        csv.append((f"table3_thr_{thr}", 0.0, f"cov={cov*100:.1f}%,prec={prec*100:.1f}%"))
    # 3b: schema heuristics
    spatial = {w: get_workload(w).spatial_ambiguous for w in WORKLOADS}
    lines += ["", "## Table 3b — Schema-specific heuristics",
              "| | Validation only | With heuristics |", "|---|---|---|"]
    for label, use_heur in (("validation", False), ("heuristics", True)):
        accepted = correct = wrong = 0
        for q, r in zip(qs, res):
            if r.signature is None:
                continue
            if use_heur:
                pol = SafetyPolicy(confidence_threshold=None,
                                   spatial_ambiguous_terms=tuple(spatial[q.schema]),
                                   spatial_qualified_phrases=QUALIFIED)
                if not gate_nl(pol, q.text, r, now=None):
                    continue
            accepted += 1
            if q.gold is not None and r.signature.key() == q.gold.key():
                correct += 1
            else:
                wrong += 1
        prec = correct / accepted if accepted else 0.0
        bypass = 1 - accepted / len(qs)
        if label == "validation":
            row_p, row_w, row_b = [f"{prec*100:.1f}%"], [str(wrong)], [f"{bypass*100:.1f}%"]
        else:
            row_p.append(f"{prec*100:.1f}%")
            row_w.append(str(wrong))
            row_b.append(f"{bypass*100:.1f}%")
    lines.append(f"| Precision | {row_p[0]} | {row_p[1]} |")
    lines.append(f"| Wrong signatures | {row_w[0]} | {row_w[1]} |")
    lines.append(f"| Bypass rate | {row_b[0]} | {row_b[1]} |")
    dt_us = (time.perf_counter() - t0) * 1e6
    csv.append(("table3_heuristics", dt_us,
                f"prec {row_p[0]}->{row_p[1]}, wrong {row_w[0]}->{row_w[1]}"))
    return lines, csv


# --------------------------------------------------------------- Table 4


def table4_overhead():
    wl = get_workload("nyc_tlc")
    queries = wl.queries(order="sequential")
    r = run_method("llmsig", wl, queries)
    sql_lat = [m for q, m in zip(queries, r.lookup_ms) if q.kind == "sql"]
    nl_lat = [m for q, m in zip(queries, r.lookup_ms) if q.kind == "nl"]
    med_s, p95_s = med_p95(sql_lat)
    med_n, p95_n = med_p95(nl_lat)
    lines = ["## Table 4a — Latency (ms) by scenario",
             "| Scenario | Median | P95 |", "|---|---|---|",
             f"| SQL canonicalize+lookup | {med_s:.3f} | {p95_s:.3f} |",
             f"| NL canonicalize+lookup (simulated LLM) | {med_n:.3f} | {p95_n:.3f} |",
             "",
             "(The paper's NL first-occurrence cost of ~1.3 s is GPT-4o-mini API "
             "latency; our simulated canonicalizer runs in-process.  The in-framework "
             "JAX canonicalizer path is measured in the quickstart example.)", ""]
    csv = [("table4_sql_lookup", med_s * 1e3, f"p95={p95_s:.3f}ms"),
           ("table4_nl_lookup", med_n * 1e3, f"p95={p95_n:.3f}ms")]

    # 4b: LRU capacity sensitivity on NYC TLC
    lines += ["## Table 4b — Hit rate (%) vs cache size (NYC TLC)",
              "| Ordering | 10% | 25% | 50% | 75% | 100% |", "|---|---|---|---|---|---|"]
    n_intents = len(wl.intents)
    for order in ("sequential", "random", "interleaved", "zipf"):
        row = [order]
        for frac in (0.10, 0.25, 0.50, 0.75, 1.0):
            cap = max(1, int(round(frac * n_intents)))
            qs = wl.queries(order=order, seed=3)
            rr = _run_llmsig_capacity(wl, qs, cap)
            row.append(f"{rr*100:.1f}")
        lines.append("| " + " | ".join(row) + " |")
        csv.append((f"table4b_{order}", 0.0, ",".join(row[1:])))
    return lines, csv


def _run_llmsig_capacity(wl, queries, capacity):
    from repro.core import MemoizedNL, SafetyPolicy, SemanticCache, SimulatedLLM
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService, QueryRequest

    backend = OlapExecutor(wl.dataset, impl="numpy")
    cache = SemanticCache(wl.schema, capacity=capacity,
                          level_mapper=wl.dataset.level_mapper())
    svc = CacheService()
    svc.register_tenant(
        schema=wl.schema, backend=backend, cache=cache,
        nl=MemoizedNL(SimulatedLLM(wl.vocab, model="oracle")),
        policy=SafetyPolicy.balanced(wl.spatial_ambiguous, qualified=QUALIFIED))
    hits = 0
    for q in queries:
        r = svc.submit(QueryRequest(sql=q.text) if q.kind == "sql"
                       else QueryRequest(nl=q.text))
        hits += r.hit
    return hits / len(queries)


# --------------------------------------------------------------- Table 5


def table5_profiles():
    from repro.core.safety import SafetyPolicy, gate_nl
    from repro.workloads import adversarial

    qs, res = _adversarial_results("gpt-4o-mini")
    spatial = {w: get_workload(w).spatial_ambiguous for w in WORKLOADS}
    profiles = {
        "Conservative": lambda s: SafetyPolicy.conservative(s, QUALIFIED),
        "Balanced": lambda s: SafetyPolicy.balanced(s, QUALIFIED),
        "Aggressive": lambda s: SafetyPolicy.aggressive(),
    }
    lines = ["## Table 5a — Configuration profiles (adversarial, N=63)",
             "| Setting | Conservative | Balanced | Aggressive |", "|---|---|---|---|"]
    rows = {"precision": [], "coverage": [], "wrong": []}
    for pname, mk in profiles.items():
        accepted = correct = wrong = 0
        for q, r in zip(qs, res):
            if r.signature is None:
                continue
            pol = mk(tuple(spatial[q.schema]))
            if not gate_nl(pol, q.text, r, now=None):
                continue
            accepted += 1
            if q.gold is not None and r.signature.key() == q.gold.key():
                correct += 1
            else:
                wrong += 1
        rows["precision"].append(f"{(correct / accepted if accepted else 0)*100:.1f}%")
        rows["coverage"].append(f"{accepted/len(qs)*100:.1f}%")
        rows["wrong"].append(str(wrong))
    lines.append("| NL precision | " + " | ".join(rows["precision"]) + " |")
    lines.append("| NL coverage | " + " | ".join(rows["coverage"]) + " |")
    lines.append("| Wrong cached | " + " | ".join(rows["wrong"]) + " |")

    lines += ["", "## Table 5b — LLM ablation (adversarial)",
              "| Model | Correct | Wrong | Invalid | Accuracy |", "|---|---|---|---|---|"]
    csv = []
    for model in ("gpt-4o-mini", "claude-3.5-haiku"):
        from repro.workloads import adversarial as adv

        q2, r2 = _adversarial_results(model)
        sc = adv.score(q2, r2)
        tot = {"correct": 0, "wrong": 0, "invalid": 0}
        for b in sc["per_type"].values():
            for k in tot:
                tot[k] += b[k]
        acc = tot["correct"] / 63
        lines.append(f"| {model} | {tot['correct']} | {tot['wrong']} | "
                     f"{tot['invalid']} | {acc*100:.1f}% |")
        csv.append((f"table5_{model}", 0.0, f"{acc*100:.1f}%"))
    return lines, csv


# ------------------------------------------------------------------- RQ4


def rq4_derivations():
    from repro.core import SemanticCache
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService, QueryRequest
    from repro.workloads import hierarchical

    wl = get_workload("ssb")
    stream = hierarchical.build_stream(20)
    lines = ["## RQ4 — Derivations on the SSB hierarchical workload",
             "| Derivations | Hit rate | Exact | Roll-up | Filter-down | False hits |",
             "|---|---|---|---|---|---|"]
    csv = []
    oracle = OlapExecutor(wl.dataset, impl="numpy")
    for enabled in (False, True):
        backend = OlapExecutor(wl.dataset, impl="numpy")
        cache = SemanticCache(wl.schema, enable_rollup=enabled,
                              enable_filterdown=enabled,
                              level_mapper=wl.dataset.level_mapper())
        svc = CacheService()
        svc.register_tenant(schema=wl.schema, backend=backend, cache=cache)
        hits = fh = 0
        t0 = time.perf_counter()
        for q in stream:
            r = svc.submit(QueryRequest(sql=q.text))
            if r.hit:
                hits += 1
                if not r.table.equals(oracle.execute(r.signature)):
                    fh += 1
        dt_us = (time.perf_counter() - t0) * 1e6 / len(stream)
        s = cache.stats
        lines.append(f"| {'on' if enabled else 'off'} | {hits/len(stream)*100:.0f}% "
                     f"| {s.hits_exact} | {s.hits_rollup} | {s.hits_filterdown} | {fh} |")
        csv.append((f"rq4_deriv_{'on' if enabled else 'off'}", dt_us,
                    f"hit={hits/len(stream)*100:.0f}%,false={fh}"))
    lines.append("\n(paper: 37% -> 80% with zero false hits)")
    return lines, csv


# ------------------------------------------------------------ BIRD-like


def birdlike_eval():
    from repro.core import SimulatedLLM
    from repro.workloads import birdlike

    qs = birdlike.build(150)
    vocabs = {w: get_workload(w).vocab for w in WORKLOADS}
    llms = {k: SimulatedLLM(v, model="gpt-4o-mini") for k, v in vocabs.items()}
    correct = wrong = invalid = 0
    t0 = time.perf_counter()
    for q in qs:
        r = llms[q.schema].canonicalize(q.text, now=None)
        if r.signature is None:
            invalid += 1
        elif r.signature.key() == q.gold.key():
            correct += 1
        else:
            wrong += 1
    dt_us = (time.perf_counter() - t0) * 1e6 / len(qs)
    acc = correct / len(qs)
    lines = ["## BIRD-like human-authored questions (N=150)",
             f"accuracy {acc*100:.1f}% (correct {correct}, wrong {wrong}, "
             f"invalid {invalid}; paper: 51.3%)"]
    return lines, [("birdlike_accuracy", dt_us, f"{acc*100:.1f}%")]


# ------------------------------------------------------ cross-PR perf trend


def _trend_extractors():
    """One headline metric (or a few) per subsystem bench — the keys each
    ``BENCH_*.json`` was gated on when its PR landed."""
    def g(d, *path, default=None):
        for p in path:
            if not isinstance(d, dict) or p not in d:
                return default
            d = d[p]
        return d

    return {
        "backend": lambda d: [
            ("fused kernel speedup", f"{g(d, 'fused_speedup'):.1f}x"),
            ("batch speedup", f"{g(d, 'batch_speedup'):.1f}x")],
        "frontend": lambda d: [
            ("SQL canonicalize qps speedup",
             f"{g(d, 'speedup_sql_qps'):.1f}x")],
        "service": lambda d: [
            ("incremental refresh speedup",
             f"{g(d, 'speedup_refresh'):.2f}x")],
        "refresh": lambda d: [
            ("warm refresh speedup", f"{g(d, 'speedup_warm'):.1f}x")],
        "cluster": lambda d: [
            ("4-shard vs 1-shard speedup",
             f"{g(d, 'speedup_4shard_vs_1shard'):.2f}x")],
        "scan": lambda d: [
            ("4-partition scan speedup",
             f"{g(d, 'speedup_4_partitions'):.2f}x")],
        "store": lambda d: [
            ("warm-restart reach fraction",
             f"{g(d, 'warm_restart', 'warm_reach_fraction'):.3f}"),
            ("cost-policy hit-bytes ratio vs LRU",
             f"{g(d, 'policy_ab', 'hit_bytes_ratio'):.2f}x")],
        "faults": lambda d: [
            ("availability at 10% faults",
             f"{g(d, 'availability', 'availability_at_10pct') * 100:.1f}%"),
            ("breaker open->served",
             f"{g(d, 'breaker_recovery', 'open_to_served_ms'):.0f}ms")],
        "obs": lambda d: [
            ("full-tracing warm-hit p50 overhead",
             f"{g(d, 'overhead', 'arms', 'tracing', 'overhead_pct_p50'):+.2f}%"),
            ("trace completeness (clean+chaos)",
             "zero missing" if g(d, 'completeness', 'zero_missing')
             else "MISSING SPANS")],
    }


def perf_trend(root=None):
    """Cross-PR performance trend: the headline metric from every
    subsystem's ``BENCH_*.json`` in one table, so a regression in any
    earlier PR's gated number is visible at a glance."""
    import json
    import os

    if root is None:
        root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    extractors = _trend_extractors()
    lines = ["## Cross-PR performance trend (headline per subsystem bench)",
             "| Bench | Metric | Value |", "|---|---|---|"]
    csv = []
    found = 0
    for name in sorted(extractors):
        path = os.path.join(root, f"BENCH_{name}.json")
        if not os.path.exists(path):
            lines.append(f"| {name} | (BENCH_{name}.json not found — "
                         f"run benchmarks/bench_{name}.py) | — |")
            continue
        found += 1
        with open(path) as f:
            data = json.load(f)
        try:
            rows = extractors[name](data)
        except (TypeError, ValueError):  # stale schema from an older run
            lines.append(f"| {name} | (unrecognized report schema) | — |")
            continue
        for metric, value in rows:
            lines.append(f"| {name} | {metric} | {value} |")
            csv.append((f"trend_{name}_{metric.split()[0]}", 0.0, value))
    lines.append("")
    lines.append(f"({found}/{len(extractors)} subsystem benches present)")
    return lines, csv
