"""Per-arch smoke tests (assignment requirement): a REDUCED config of each
family runs one forward/train step on CPU with correct shapes and no NaNs,
plus a prefill-vs-forward teacher-forcing consistency check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, CONFIGS, reduced

B, S = 2, 32


def make_batch(cfg):
    rng = np.random.default_rng(0)
    labels = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    if cfg.embed_inputs:
        return {"embeddings": jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), cfg.dtype),
            "labels": jnp.asarray(labels)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32),
            "labels": jnp.asarray(labels)}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_smoke_forward_and_train_step(name):
    cfg = reduced(name)
    mod = cfg.build()
    params = mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), name
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves), name
    # one optimizer step
    from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

    new_p, _, gnorm = adamw_update(AdamWConfig(), params, grads, init_opt_state(params))
    assert np.isfinite(float(gnorm))
    assert jax.tree.structure(new_p) == jax.tree.structure(params)


@pytest.mark.parametrize("name", ["qwen3-32b", "kimi-k2-1t-a32b", "mamba2-780m",
                                  "zamba2-7b", "musicgen-large"])
def test_prefill_decode_consistency(name):
    """Teacher forcing: decode-step logits must match full-forward logits.

    MoE runs with a generous capacity factor: capacity *truncation* is a
    train-time policy that legitimately differs between a 1-token decode and
    a full forward, so the consistency oracle needs drop-free routing."""
    cfg = dataclasses.replace(reduced(name), dtype=jnp.float32,
                              capacity_factor=64.0)
    mod = cfg.build()
    params = mod.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    kw = ({"embeddings": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)}
          if cfg.embed_inputs else {"tokens": tokens})
    full = np.asarray(mod.forward(cfg, params, **kw))  # (B, S, V)

    logits, caches, pos = mod.prefill(cfg, params, cache_len=S + 8, **kw)
    np.testing.assert_allclose(np.asarray(logits), full[:, -1], rtol=2e-2, atol=2e-2)
    if cfg.embed_inputs:
        return  # decode continues in token space; no teacher-forcing oracle
    # step one token forward and compare against forward over extended seq
    nxt = tokens[:, -1]  # arbitrary teacher-forced token
    logits2, caches, pos = mod.decode_step(cfg, params, nxt, caches, pos)
    ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    full2 = np.asarray(mod.forward(cfg, params, tokens=ext))
    np.testing.assert_allclose(np.asarray(logits2), full2[:, -1], rtol=3e-2, atol=3e-2)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED) == 10
    kinds = {CONFIGS[a].kind for a in ASSIGNED}
    assert kinds == {"dense", "moe", "ssm", "hybrid"}


def test_param_counts_sane():
    assert CONFIGS["nemotron-4-340b"].param_count() / 1e9 == pytest.approx(340, rel=0.06)
    assert CONFIGS["kimi-k2-1t-a32b"].param_count() / 1e9 == pytest.approx(1000, rel=0.30)
    active = CONFIGS["kimi-k2-1t-a32b"].active_param_count()
    assert active / 1e9 == pytest.approx(32, rel=0.45)
    assert CONFIGS["mamba2-780m"].param_count() / 1e6 == pytest.approx(780, rel=0.25)
