"""Incremental snapshot refresh (ISSUE 3).

Tentpole: append-only delta ingest (``Dataset.append_rows`` / partition
metadata), partition-bounded batch execution, and the ``core.refresh`` merge
algebra that brings affected cached entries current at delta cost.  The key
property throughout: a merged table must equal a full recompute of the same
signature over the grown fact table — zero drift.

Satellites covered here: NaN-clean MIN/MAX oracle + roll-up, ``put``
overwrite provenance, spill shrink/atomic-manifest behavior, and the merge
property tests.  The whole module runs with RuntimeWarnings as errors so
the NaN fixes stay fixed.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import Measure, SemanticCache, Signature, TimeWindow
from repro.core.refresh import merge_tables, refreshable
from repro.core.sql_canon import SQLCanonicalizer
from repro.core.table import ResultTable
from repro.olap.columnar import ColumnData
from repro.olap.executor import OlapExecutor
from repro.workloads import ssb

from benchmarks.bench_refresh import make_delta as _bench_make_delta

pytestmark = pytest.mark.filterwarnings("error::RuntimeWarning")

J = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
     "JOIN customer ON lineorder.lo_custkey = customer.c_key ")

COMPOSABLE = (f"SELECT c_region, SUM(lo_revenue) AS r, COUNT(*) AS n, "
              f"MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
              f"FROM lineorder {J}GROUP BY c_region")


def make_delta(ds, n, seed=0, year=1998):
    """Seeded wrapper over the benchmark's SSB delta-row generator (one
    shared implementation; the fact schema only needs updating there)."""
    return _bench_make_delta(ds, n, np.random.default_rng(seed), year=year)


@pytest.fixture()
def wl():
    """Fresh (mutable) small SSB per test — appends must never leak into the
    session-scoped fixtures."""
    return ssb.build(n_fact=3000, seed=0)


# ------------------------------------------------------------ append path


class TestAppend:
    def test_column_append_numeric_and_date_iso(self):
        c = ColumnData("float", np.asarray([1.0, 2.0]))
        c.append(np.asarray([3.5]))
        assert c.data.tolist() == [1.0, 2.0, 3.5]
        d = ColumnData("date", np.asarray(["1994-01-01"]))
        d.append(np.asarray(["1994-01-03"]))
        assert (d.data[1] - d.data[0]) == 2  # ISO converted to days

    def test_column_append_str_reencodes_unseen_vocab(self):
        c = ColumnData("str", np.asarray(["b", "a", "b"]))
        old = c.encode_value("b")
        c.append(np.asarray(["ab", "b"]))  # 'ab' sorts between 'a' and 'b'
        assert c.vocab.tolist() == ["a", "ab", "b"]
        assert c.encode_value("b") != old  # codes shifted: full re-encode
        assert c.decode(c.data).tolist() == ["b", "a", "b", "ab", "b"]

    def test_append_rows_partitions_version_and_extent(self, wl):
        ds = wl.dataset
        n0, v0 = ds.fact.num_rows, ds.version
        part = ds.append_rows(make_delta(ds, 500), snapshot_id="snap1")
        assert ds.fact.num_rows == n0 + 500 and ds.version == v0 + 1
        assert (part.start_row, part.end_row) == (n0, n0 + 500)
        assert part.date_start.startswith("1998-")
        assert part.date_end > part.date_start  # end exclusive, past max date
        assert ds.snapshot_id == "snap1"
        # base partition recorded retroactively, delta partition appended
        assert [(p.start_row, p.end_row) for p in ds.partitions] == \
            [(0, n0), (n0, n0 + 500)]

    def test_append_rows_is_atomic_on_bad_values(self, wl):
        """A mid-delta conversion failure (unparseable date) must leave the
        dataset fully intact — not ragged columns with half the delta in."""
        ds = wl.dataset
        n0, v0 = ds.fact.num_rows, ds.version
        bad = make_delta(ds, 10)
        bad["lo_date"] = np.asarray(["1998-01-01"] * 9 + ["not-a-date"])
        with pytest.raises(ValueError):
            ds.append_rows(bad)
        assert ds.fact.num_rows == n0 and ds.version == v0
        assert all(c.n == n0 for c in ds.fact.columns.values())

    def test_append_rows_rejects_lossy_float_to_int(self, wl):
        """Fractional values for an int fact column must be rejected at
        staging, not silently truncated into wrong aggregates."""
        ds = wl.dataset
        n0 = ds.fact.num_rows
        bad = make_delta(ds, 10)
        bad["lo_quantity"] = bad["lo_quantity"] + 0.5
        with pytest.raises(ValueError, match="lossy"):
            ds.append_rows(bad)
        assert ds.fact.num_rows == n0 and ds.version == 0

    def test_append_rows_rejects_out_of_range_fk(self, wl):
        """An FK pointing past its dimension would commit fine and crash
        every later scan's gather — rejected at staging, dataset intact."""
        ds = wl.dataset
        n0 = ds.fact.num_rows
        bad = make_delta(ds, 10)
        bad["lo_custkey"][3] = ds.dims["customer"].num_rows  # one past the end
        with pytest.raises(ValueError, match="lo_custkey"):
            ds.append_rows(bad)
        assert ds.fact.num_rows == n0 and ds.version == 0

    def test_append_rows_rejects_ragged_and_mismatched(self, wl):
        ds = wl.dataset
        delta = make_delta(ds, 10)
        bad = dict(delta)
        bad.pop("lo_revenue")
        with pytest.raises(ValueError, match="missing"):
            ds.append_rows(bad)
        bad = dict(delta)
        bad["lo_revenue"] = bad["lo_revenue"][:5]
        with pytest.raises(ValueError, match="ragged"):
            ds.append_rows(bad)

    def test_slice_rows_views_delta_only(self, wl):
        ds = wl.dataset
        n0 = ds.fact.num_rows
        ds.append_rows(make_delta(ds, 200))
        view = ds.slice_rows(n0, n0 + 200)
        assert view.fact.num_rows == 200
        assert view.dims is ds.dims  # dimensions shared, not copied
        np.testing.assert_array_equal(
            view.fact.columns["lo_revenue"].data,
            ds.fact.columns["lo_revenue"].data[n0:])


class TestExecutorDelta:
    def test_executor_resyncs_after_append(self, wl):
        canon = SQLCanonicalizer(wl.schema)
        sig = canon.canonicalize(COMPOSABLE)
        ex = OlapExecutor(wl.dataset, impl="numpy")
        before = ex.execute(sig)
        wl.dataset.append_rows(make_delta(wl.dataset, 400))
        after = ex.execute(sig)  # same executor: caches must resync
        fresh = OlapExecutor(wl.dataset, impl="numpy").execute(sig)
        assert after.equals(fresh)
        assert not after.equals(before)  # the delta visibly changed the result

    def test_append_keeps_dim_uploads_on_device(self, wl):
        """Fused path: a fact append must not evict the dimension-column
        uploads — they are dim-row-aligned and immutable, and keeping them
        is what makes a delta tick upload only delta-sized fact data."""
        canon = SQLCanonicalizer(wl.schema)
        # the c_region predicate puts a dimension column on device (encoded
        # range bounds over the FK-gathered customer column)
        sig = canon.canonicalize(
            f"SELECT c_nation, SUM(lo_revenue) AS r, COUNT(*) AS n "
            f"FROM lineorder {J}WHERE c_region = 'ASIA' GROUP BY c_nation")
        ex = OlapExecutor(wl.dataset, impl="xla")
        ex.execute(sig)
        dev = wl.dataset._device
        dim_keys = list(dev._dim_store)
        assert dim_keys  # the customer.c_region upload
        part = wl.dataset.append_rows(make_delta(wl.dataset, 200))
        assert wl.dataset._device is dev  # mirror survives the append
        assert not dev._store  # fact-aligned arrays dropped
        assert sorted(dev._dim_store) == sorted(dim_keys)  # dims survive
        got = ex.execute_batch([sig], partition=(part.start_row, part.end_row))
        oracle = OlapExecutor(
            wl.dataset.slice_rows(part.start_row, part.end_row), impl="numpy")
        assert got[0].equals(oracle.execute(sig))

    @pytest.mark.parametrize("impl", ["numpy", "xla"])
    def test_partition_bounded_batch_equals_slice_oracle(self, wl, impl):
        canon = SQLCanonicalizer(wl.schema)
        sigs = [canon.canonicalize(COMPOSABLE),
                canon.canonicalize(
                    f"SELECT c_nation, SUM(lo_revenue) AS r, COUNT(*) AS n, "
                    f"MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
                    f"FROM lineorder {J}WHERE c_region = 'ASIA' "
                    f"GROUP BY c_nation")]
        ds = wl.dataset
        n0 = ds.fact.num_rows
        part = ds.append_rows(make_delta(ds, 300))
        ex = OlapExecutor(ds, impl=impl)
        rows0 = ex.rows_scanned
        got = ex.execute_batch(sigs, partition=(part.start_row, part.end_row))
        # scan cost is proportional to the delta, not the table
        assert ex.rows_scanned - rows0 <= len(sigs) * 300
        oracle = OlapExecutor(ds.slice_rows(n0, n0 + 300), impl="numpy")
        for s, t in zip(sigs, got):
            assert t.equals(oracle.execute(s))


# ----------------------------------------------------------- merge algebra


def _sig(measures, levels=("customer.c_region",)):
    return Signature(schema="ssb", measures=tuple(measures), levels=levels)


def _direct(sig, base_rows, delta_rows):
    """Reference: aggregate base+delta rows directly with plain numpy."""
    keys = np.concatenate([base_rows[0], delta_rows[0]])
    vals = np.concatenate([base_rows[1], delta_rows[1]])
    out_k = np.unique(keys)
    cols = {sig.levels[0]: out_k}
    for i, m in enumerate(sig.measures):
        res = []
        for k in out_k:
            sel = vals[keys == k]
            if m.agg in ("SUM", "COUNT"):
                res.append(sel.sum())  # NaN propagates, like the executor
            elif m.agg == "MIN":
                res.append(sel.min())
            else:
                res.append(sel.max())
        cols[f"m{i}"] = np.asarray(res, np.float64)
    return ResultTable(cols)


class TestMergeAlgebra:
    def test_refreshable_gate(self):
        assert refreshable(_sig([Measure("SUM", "x"), Measure("MIN", "x")]))
        assert not refreshable(_sig([Measure("AVG", "x")]))
        assert not refreshable(_sig([Measure("COUNT", "x", distinct=True)]))
        assert not refreshable(
            _sig([Measure("SUM", "x")]).replace(limit=5))

    def test_merge_group_union_and_extremes(self):
        sig = _sig([Measure("SUM", "x"), Measure("MIN", "x"),
                    Measure("MAX", "x"), Measure("COUNT", "*")])
        base = ResultTable({
            "customer.c_region": np.asarray(["A", "B"]),
            "m0": np.asarray([10.0, 4.0]), "m1": np.asarray([1.0, 2.0]),
            "m2": np.asarray([9.0, 2.0]), "m3": np.asarray([3.0, 1.0])})
        delta = ResultTable({
            "customer.c_region": np.asarray(["B", "C"]),
            "m0": np.asarray([6.0, 7.0]), "m1": np.asarray([0.5, 7.0]),
            "m2": np.asarray([0.5, 7.0]), "m3": np.asarray([2.0, 1.0])})
        got = merge_tables(sig, base, delta)
        assert got.columns["customer.c_region"].tolist() == ["A", "B", "C"]
        assert got.columns["m0"].tolist() == [10.0, 10.0, 7.0]  # SUM adds
        assert got.columns["m1"].tolist() == [1.0, 0.5, 7.0]  # MIN combines
        assert got.columns["m2"].tolist() == [9.0, 2.0, 7.0]  # MAX combines
        assert got.columns["m3"].tolist() == [3.0, 3.0, 1.0]  # COUNT adds

    def test_merge_nan_poisons_like_recompute(self):
        """A NaN that reached a cached/delta group value keeps poisoning the
        merged group — and does so without RuntimeWarnings (module-level
        filterwarnings turns them into errors)."""
        sig = _sig([Measure("MIN", "x"), Measure("SUM", "x")])
        base = ResultTable({
            "customer.c_region": np.asarray(["A", "B"]),
            "m0": np.asarray([np.nan, 2.0]), "m1": np.asarray([np.nan, 5.0])})
        delta = ResultTable({
            "customer.c_region": np.asarray(["A", "B"]),
            "m0": np.asarray([1.0, 3.0]), "m1": np.asarray([1.0, 1.0])})
        got = merge_tables(sig, base, delta)
        assert np.isnan(got.columns["m0"][0]) and got.columns["m0"][1] == 2.0
        assert np.isnan(got.columns["m1"][0]) and got.columns["m1"][1] == 6.0

    def test_merge_global_aggregate(self):
        sig = _sig([Measure("SUM", "x"), Measure("MIN", "x")], levels=())
        base = ResultTable({"m0": np.asarray([4.0]), "m1": np.asarray([2.0])})
        delta = ResultTable({"m0": np.asarray([1.5]), "m1": np.asarray([0.5])})
        got = merge_tables(sig, base, delta)
        assert got.columns["m0"][0] == 5.5 and got.columns["m1"][0] == 0.5

    def test_merge_rejects_non_composable(self):
        sig = _sig([Measure("AVG", "x")])
        t = ResultTable({"customer.c_region": np.asarray(["A"]),
                         "m0": np.asarray([1.0])})
        with pytest.raises(ValueError, match="not mergeable"):
            merge_tables(sig, t, t)

    @settings(max_examples=40, deadline=None)
    @given(
        aggs=st.lists(st.sampled_from(["SUM", "COUNT", "MIN", "MAX"]),
                      min_size=1, max_size=4),
        base_n=st.integers(0, 12),
        delta_n=st.integers(0, 12),
        data=st.data(),
    )
    def test_merge_equals_direct_aggregate_property(self, aggs, base_n,
                                                    delta_n, data):
        """merge(base, delta) == aggregate(base rows ++ delta rows) for every
        composable agg across arbitrary group unions."""
        if base_n + delta_n == 0:
            return
        sig = _sig([Measure(a, "x") for a in aggs])
        groups = np.asarray(list("ABCDE"))

        def rows(n, tag):
            k = np.asarray(data.draw(
                st.lists(st.sampled_from(list("ABCDE")), min_size=n,
                         max_size=n), label=f"{tag}_keys"))
            v = np.asarray(data.draw(
                st.lists(st.floats(-100, 100, allow_nan=False), min_size=n,
                         max_size=n), label=f"{tag}_vals"))
            return k, v

        def agg_side(k, v):
            uk = np.unique(k)
            cols = {sig.levels[0]: uk}
            for i, m in enumerate(sig.measures):
                per = [v[k == g] for g in uk]
                if m.agg in ("SUM", "COUNT"):
                    cols[f"m{i}"] = np.asarray([p.sum() for p in per])
                elif m.agg == "MIN":
                    cols[f"m{i}"] = np.asarray([p.min() for p in per])
                else:
                    cols[f"m{i}"] = np.asarray([p.max() for p in per])
            return ResultTable(cols)

        bk, bv = rows(base_n, "base")
        dk, dv = rows(delta_n, "delta")
        merged = merge_tables(sig, agg_side(bk, bv), agg_side(dk, dv))
        direct = _direct(sig, (bk, bv), (dk, dv))
        assert merged.equals(direct)


# ------------------------------------------------- end-to-end service path


def _service(wl, impl="numpy"):
    from repro.service import CacheService

    backend = OlapExecutor(wl.dataset, impl=impl)
    svc = CacheService()
    svc.register_tenant("t", schema=wl.schema, backend=backend,
                        cache=SemanticCache(
                            wl.schema, level_mapper=wl.dataset.level_mapper()))
    return svc, svc.tenant("t"), backend


class TestServiceRefresh:
    AVG_TILE = (f"SELECT c_region, AVG(lo_quantity) AS q FROM lineorder "
                f"{J}GROUP BY c_region")
    TOPK_TILE = (f"SELECT c_nation, SUM(lo_revenue) AS r FROM lineorder "
                 f"{J}GROUP BY c_nation ORDER BY r DESC LIMIT 3")
    CLOSED_TILE = (f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder "
                   f"{J}WHERE d_year = 1992 GROUP BY c_region")

    def test_refresh_keeps_working_set_and_matches_recompute(self, wl):
        from repro.service import QueryRequest

        svc, tenant, backend = _service(wl)
        tiles = [COMPOSABLE, self.AVG_TILE, self.TOPK_TILE, self.CLOSED_TILE]
        svc.submit_batch([QueryRequest(sql=q, tenant="t") for q in tiles])
        assert len(tenant.cache) == 4
        rep = svc.advance_snapshot("t", "snap1",
                                   delta=make_delta(wl.dataset, 400))
        # composable windowless tile merged; AVG + ORDER BY/LIMIT recomputed;
        # the 1992 closed window is outside the 1998 delta extent: untouched
        assert rep.appended_rows == 400
        assert rep.refreshed == 1 and rep.recomputed == 2
        assert rep.dropped == 0 and rep.unaffected == 1
        assert tenant.cache.stats.refreshes == 1
        assert tenant.cache.stats.refresh_fallbacks == 2
        oracle = OlapExecutor(wl.dataset, impl="numpy")
        served = svc.submit_batch(
            [QueryRequest(sql=q, tenant="t", read_only=True) for q in tiles])
        for r in served:
            assert r.status == "hit_exact"
            assert r.table.equals(oracle.execute(r.signature),
                                  ordered=bool(r.signature.order_by))
        # provenance: refreshed tiles advertise the snapshot they reflect
        assert served[0].source_snapshot == "snap1"
        assert "snapshot:snap1" in served[0].provenance
        assert served[3].source_snapshot == "snap0"  # untouched closed window

    def test_update_extent_unions_with_delta_dates(self, wl):
        """A caller-claimed update range narrower than the delta's real date
        extent must not leave intersecting entries stale-but-served: the
        extent is unioned with ground truth from the appended rows."""
        from repro.service import QueryRequest

        svc, tenant, _ = _service(wl)
        svc.submit(QueryRequest(sql=self.CLOSED_TILE, tenant="t"))  # 1992
        delta = make_delta(wl.dataset, 200, year=1992)
        rep = svc.advance_snapshot("t", "snap1", "1998-01-01", "1998-02-01",
                                   delta=delta)
        assert rep.updated_start <= "1992-12-31" < rep.updated_end
        assert rep.refreshed == 1 and rep.unaffected == 0
        oracle = OlapExecutor(wl.dataset, impl="numpy")
        served = svc.submit(QueryRequest(sql=self.CLOSED_TILE, tenant="t",
                                         read_only=True))
        assert served.hit and served.table.equals(
            oracle.execute(served.signature))

    def test_half_open_extent_stays_conservative(self, wl):
        """One missing bound means unknown update extent: the delta's own
        dates must not silently close it, or entries inside the claimed
        region would be skipped — everything refreshes instead."""
        from repro.service import QueryRequest

        svc, tenant, _ = _service(wl)
        svc.submit(QueryRequest(sql=self.CLOSED_TILE, tenant="t"))  # 1992
        rep = svc.advance_snapshot("t", "snap1", updated_start="2024-01-01",
                                   delta=make_delta(wl.dataset, 100))
        assert rep.updated_end is None  # still unknown
        assert rep.refreshed == 1 and rep.unaffected == 0

    def test_refresh_false_keeps_drop_semantics(self, wl):
        from repro.service import QueryRequest

        svc, tenant, _ = _service(wl)
        svc.submit(QueryRequest(sql=COMPOSABLE, tenant="t"))
        rep = svc.advance_snapshot(
            "t", "snap1", delta=make_delta(wl.dataset, 100), refresh=False)
        assert rep.dropped == 1 and rep.refreshed == 0
        assert len(tenant.cache) == 0

    def test_open_ended_window_is_refreshed(self, wl):
        svc, tenant, backend = _service(wl)
        sig = Signature(
            schema=wl.schema.name,
            measures=(Measure("SUM", "lineorder.lo_revenue"),),
            levels=("customer.c_region",),
            time_window=TimeWindow("1997-01-01", "1999-01-01", open_ended=True))
        tenant.cache.put(sig, backend.execute(sig), snapshot_id="snap0")
        rep = svc.advance_snapshot("t", "snap1",
                                   delta=make_delta(wl.dataset, 300))
        assert rep.refreshed == 1
        fresh = OlapExecutor(wl.dataset, impl="numpy").execute(sig)
        assert tenant.cache.entry(sig.key()).table.equals(fresh)
        assert tenant.cache.entry(sig.key()).refreshes == 1


# ------------------------------------------------- satellite regressions


class TestPutOverwriteProvenance:
    def test_overwrite_updates_origin_and_stored_at(self, wl):
        canon = SQLCanonicalizer(wl.schema)
        backend = OlapExecutor(wl.dataset, impl="numpy")
        cache = SemanticCache(wl.schema)
        sig = canon.canonicalize(COMPOSABLE)
        t = backend.execute(sig)
        cache.put(sig, t, origin="nl", snapshot_id="snap0")
        e = cache.entry(sig.key())
        first_stored = e.stored_at
        cache.put(sig, t, origin="sql", snapshot_id="snap1")
        assert e.origin == "sql"  # was stuck at 'nl' before the fix
        assert e.snapshot_id == "snap1"
        assert e.stored_at >= first_stored  # re-stamped (monotonic clock)
        assert cache.lookup(sig).source_origin == "sql"


class TestSpillShrink:
    def _fill(self, wl, n):
        canon = SQLCanonicalizer(wl.schema)
        backend = OlapExecutor(wl.dataset, impl="numpy")
        cache = SemanticCache(wl.schema)
        years = (1993, 1994, 1995, 1996)[:n]
        for y in years:
            sig = canon.canonicalize(
                f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder "
                f"{J}WHERE d_year = {y} GROUP BY c_region")
            cache.put(sig, backend.execute(sig))
        return cache

    def test_shrinking_respill_removes_stale_entry_files(self, wl, tmp_path):
        import json
        import os

        from repro.core.cache import load_cache, save_cache

        spill = str(tmp_path / "spill")
        assert save_cache(self._fill(wl, 3), spill) == 3
        assert sum(f.endswith(".npz") for f in os.listdir(spill)) == 3
        assert save_cache(self._fill(wl, 1), spill) == 1
        files = sorted(f for f in os.listdir(spill) if f.endswith(".npz"))
        with open(os.path.join(spill, "manifest.json")) as f:
            manifest = json.load(f)
        # exactly the one manifest-listed file survives; the two stale
        # entries of the larger spill (and any .tmp orphans) are gone
        assert files == [manifest[0]["file"]]
        assert not any(f.endswith(".tmp") for f in os.listdir(spill))
        warm = SemanticCache(wl.schema)
        assert load_cache(warm, spill) == 1


class TestNaNWarningClean:
    """Satellites 1 & 3: NaN-bearing measures through the numpy MIN/MAX
    oracle and the roll-up re-aggregation must be warning-clean (the module
    filter turns RuntimeWarnings into errors) and match a direct recompute."""

    @pytest.fixture()
    def nan_wl(self):
        w = ssb.build(n_fact=3000, seed=3)
        rev = w.dataset.fact.columns["lo_revenue"].data
        rev[np.random.default_rng(0).random(len(rev)) < 0.05] = np.nan
        return w

    MINMAX = (f"SELECT c_city, MIN(lo_revenue) AS lo, MAX(lo_revenue) AS hi, "
              f"SUM(lo_revenue) AS r, COUNT(*) AS n FROM lineorder "
              f"{J}GROUP BY c_city")

    def test_oracle_minmax_warning_clean(self, nan_wl):
        canon = SQLCanonicalizer(nan_wl.schema)
        backend = OlapExecutor(nan_wl.dataset, impl="numpy")
        sig = canon.canonicalize(self.MINMAX)
        t = backend.execute(sig)  # raised RuntimeWarning-as-error before fix
        # NaN groups exist (propagation preserved), but no warnings fired
        assert any(np.isnan(t.columns["m0"]))

    def test_nan_rollup_equals_recompute(self, nan_wl):
        canon = SQLCanonicalizer(nan_wl.schema)
        backend = OlapExecutor(nan_wl.dataset, impl="numpy")
        cache = SemanticCache(nan_wl.schema,
                              level_mapper=nan_wl.dataset.level_mapper())
        fine = canon.canonicalize(self.MINMAX)
        cache.put(fine, backend.execute(fine))
        for coarse_lvl in ("c_nation", "c_region"):
            coarse = canon.canonicalize(
                self.MINMAX.replace("c_city", coarse_lvl))
            r = cache.lookup(coarse)
            assert r.status == "hit_rollup"
            assert r.table.equals(backend.execute(coarse))
