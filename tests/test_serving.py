"""Serving engine + grammar-constrained JSON decoding."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import reduced
from repro.serving.json_decode import JsonSigAutomaton, constrained_sample


class TestAutomaton:
    def test_legal_prefixes(self):
        a = JsonSigAutomaton()
        for p in ['', '{', '{"schema"', '{"schema": "ssb", "measures": [{"agg": "SUM"',
                  '{"measures": [{"agg": "SUM", "expr": "t.x"}]}']:
            assert a.is_legal_prefix(p), p

    def test_illegal_prefixes(self):
        a = JsonSigAutomaton()
        for p in ['}', 'x{', '{]', '{"a": }}', '{)']:
            assert not a.is_legal_prefix(p), p

    def test_completion(self):
        a = JsonSigAutomaton()
        assert a.is_complete('{"schema": "s", "measures": [{"agg": "SUM", "expr": "t.x"}]}')
        assert not a.is_complete('{"schema": "s"}')
        assert not a.is_complete('{"schema": "s", "measures": [')

    def test_mask_blocks_illegal(self):
        a = JsonSigAutomaton()
        vocab = ['{', '}', '[', ']', '"agg"', 'xx(', ':', ' ']
        mask = a.token_mask("", vocab)
        assert mask[0] and not mask[1]  # must open with '{'
        assert not mask[5]

    def test_constrained_sample_stays_legal(self):
        rng = np.random.default_rng(0)
        a = JsonSigAutomaton()
        vocab = list('{}[]":,') + ['"schema"', '"measures"', '"agg"', '"SUM"',
                                   '"expr"', '"t.x"', ' ', 'a', 'b', '1']
        prefix = ""
        for _ in range(40):
            logits = rng.normal(size=len(vocab)).astype(np.float32)
            nid = constrained_sample(logits, prefix, vocab, a)
            if nid < 0:
                break
            prefix += vocab[nid]
            assert a.is_legal_prefix(prefix), prefix


class TestEngine:
    @pytest.fixture(scope="class")
    def engine(self, ssb_small):
        from repro.serving.engine import ServingEngine
        from repro.training.tokenizer import build_tokenizer

        cfg = dataclasses.replace(reduced("canonicalizer-100m"), vocab=4096)
        tok = build_tokenizer([ssb_small])
        mod = cfg.build()
        params = mod.init_params(cfg, jax.random.PRNGKey(0))
        return ServingEngine(cfg, params, tok, max_len=128)

    def test_batched_generate(self, engine):
        outs = engine.generate(["total revenue by year", "number of orders"],
                               max_new_tokens=8)
        assert len(outs) == 2
        assert all(len(o["tokens"]) <= 8 for o in outs)
        assert all(np.isfinite(o["logprob"]) for o in outs)

    def test_constrained_generate_stays_legal(self, engine):
        a = JsonSigAutomaton()
        out = engine.generate(["q"], max_new_tokens=24, constrained=True)[0]
        assert a.is_legal_prefix(out["text"]), out["text"]

    def test_canonicalizer_service_protocol(self, engine, ssb_small):
        """Untrained model: output must be either a valid signature or a
        safe failure (never an exception) — the NLCanonicalizer contract."""
        from repro.serving.engine import CanonicalizerService

        svc = CanonicalizerService(engine, "ssb")
        res = svc.canonicalize("total revenue by year")
        assert res.confidence >= 0
        assert (res.signature is None) == (res.error is not None)
