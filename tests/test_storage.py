"""Tiered durable store: manifest crash-safety, cold-tier payload
verification, cost-aware admission, warm restart, and the differential
oracle (a tiered cache must serve bit-identical results to an all-hot one,
modulo the ``tier:cold`` provenance tag — including across a kill/restart).
"""
import json
import os
import time
import types

import numpy as np
import pytest

from repro.core import SemanticCache
from repro.core.cache import load_cache, save_cache
from repro.core.sql_canon import SQLCanonicalizer
from repro.core.table import ResultTable
from repro.olap.executor import OlapExecutor
from repro.storage import policy as storage_policy
from repro.resilience import faults
from repro.storage.coldstore import ColdTier, payload_name
from repro.storage.engine import TieredStore, entry_meta
from repro.storage.manifest import DurableManifest

JOINS = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
         "JOIN customer ON lineorder.lo_custkey = customer.c_key ")


def q(where="d_year = 1994", group="c_region"):
    return (f"SELECT {group}, SUM(lo_revenue) AS r, COUNT(*) AS n "
            f"FROM lineorder {JOINS}WHERE {where} GROUP BY {group}")


@pytest.fixture(scope="module")
def env(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    backend = OlapExecutor(ssb_small.dataset, impl="numpy")
    return ssb_small, canon, backend


def fresh_cache(wl, **kw):
    return SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(), **kw)


def year_queries(canon, backend, years=(1992, 1993, 1994, 1995, 1996, 1997)):
    sigs = [canon.canonicalize(q(f"d_year = {y}")) for y in years]
    return [(s, backend.execute(s)) for s in sigs]


# --------------------------------------------------------------- manifest


class TestManifest:
    def test_wal_roundtrip_put_meta_del(self, tmp_path):
        m = DurableManifest(str(tmp_path))
        m.append({"key": "a", "v": 1})
        m.append({"key": "b", "v": 2})
        m.append({"key": "a", "op": "meta", "hits": 7, "lru_stamp": 99})
        m.append({"key": "b", "op": "del"})
        m.close()
        records, report = DurableManifest(str(tmp_path)).replay()
        assert set(records) == {"a"}
        assert records["a"]["hits"] == 7 and records["a"]["lru_stamp"] == 99
        assert report["tombstones"] == 1 and report["torn_records"] == 0

    def test_torn_tail_and_crc_corruption_skipped(self, tmp_path):
        m = DurableManifest(str(tmp_path))
        m.append({"key": "a", "v": 1})
        m.append({"key": "b", "v": 2})
        m.close()
        log = tmp_path / "manifest.log"
        lines = log.read_bytes().splitlines(keepends=True)
        # corrupt record b's payload without touching its crc frame
        lines[1] = lines[1].replace(b'"v":2', b'"v":3')
        # and simulate a kill mid-append: torn half record at the tail
        log.write_bytes(b"".join(lines) + b'{"key":"c","op":"pu')
        records, report = DurableManifest(str(tmp_path)).replay()
        assert set(records) == {"a"}
        assert report["torn_records"] == 2

    def test_orphan_meta_is_not_a_record(self, tmp_path):
        m = DurableManifest(str(tmp_path))
        m.append({"key": "ghost", "op": "meta", "hits": 3})
        m.close()
        records, report = DurableManifest(str(tmp_path)).replay()
        assert records == {} and report["orphan_meta"] == 1

    def test_checkpoint_truncates_log_and_replays_identically(self, tmp_path):
        m = DurableManifest(str(tmp_path))
        m.append({"key": "a", "v": 1})
        m.append({"key": "b", "v": 2})
        before, _ = DurableManifest(str(tmp_path)).replay()
        m.checkpoint(before.values())
        m.close()
        assert (tmp_path / "manifest.log").read_bytes() == b""
        after, report = DurableManifest(str(tmp_path)).replay()
        assert after == before
        assert report["checkpoint_records"] == 2 and report["log_records"] == 0

    def test_crash_between_checkpoint_and_truncate_is_idempotent(self, tmp_path):
        m = DurableManifest(str(tmp_path))
        m.append({"key": "a", "v": 1})
        m.close()
        records, _ = DurableManifest(str(tmp_path)).replay()
        # checkpoint written but the log truncation "lost to a crash":
        # re-append the pre-checkpoint log contents after checkpointing
        log_bytes = (tmp_path / "manifest.log").read_bytes()
        m2 = DurableManifest(str(tmp_path))
        m2.checkpoint(records.values())
        m2.close()
        (tmp_path / "manifest.log").write_bytes(log_bytes)
        after, _ = DurableManifest(str(tmp_path)).replay()
        assert after == records


# -------------------------------------------------------------- cold tier


class TestColdTier:
    def _table(self):
        return ResultTable(columns={"d": np.arange(8), "v": np.arange(8.0)})

    def test_payload_roundtrip_and_sha_verification(self, tmp_path):
        tier = ColdTier(str(tmp_path))
        t = self._table()
        payload = tier.write_payload("k" * 40, t)
        rec = {"key": "k" * 40, **payload}
        back = tier.read_payload(rec)
        assert back is not None and back.equals(t)
        # same-size bit flip: sha catches what file_bytes framing cannot
        fpath = tmp_path / payload["file"]
        data = bytearray(fpath.read_bytes())
        data[len(data) // 2] ^= 0xFF
        fpath.write_bytes(bytes(data))
        assert tier.read_payload(rec) is None

    def test_open_cleans_orphan_payloads_and_tmp_files(self, tmp_path):
        (tmp_path / "entry_orphan.npz").write_bytes(b"junk")
        (tmp_path / f"{payload_name('x' * 30)}.7.123.tmp").write_bytes(b"half")
        tier = ColdTier(str(tmp_path))
        assert tier.open() == {}
        assert tier.replay_report["orphan_files"] == 2
        leftovers = [f for f in os.listdir(tmp_path)
                     if f.endswith(".npz") or f.endswith(".tmp")]
        assert leftovers == []


# ----------------------------------------------------------------- policy


def _fake_entry(now, *, hits=0, idle=0.0, cost_ms=1.0, nbytes=1000):
    return types.SimpleNamespace(hits=hits, last_used_at=now - idle,
                                 stored_at=now - idle, cost_ms=cost_ms,
                                 table_nbytes=nbytes)


class TestPolicy:
    def test_decayed_hits_halves_per_half_life(self):
        now = 1000.0
        e = _fake_entry(now, hits=8, idle=600.0)
        assert storage_policy.decayed_hits(e, now, 600.0) == pytest.approx(4.0)
        assert storage_policy.decayed_hits(e, now + 600.0, 600.0) == pytest.approx(2.0)

    def test_score_orders_by_recompute_value_density(self):
        now = 1000.0
        keeper = _fake_entry(now, hits=10, idle=1.0, cost_ms=50.0, nbytes=1000)
        victim = _fake_entry(now, hits=0, idle=3600.0, cost_ms=0.1, nbytes=100000)
        s_keep = storage_policy.cost_benefit_score(keeper, now, 600.0)
        s_drop = storage_policy.cost_benefit_score(victim, now, 600.0)
        assert s_keep > s_drop

    def test_make_policy(self):
        assert storage_policy.make_policy("lru").name == "lru"
        assert storage_policy.make_policy("cost").name == "cost"
        with pytest.raises(ValueError):
            storage_policy.make_policy("clock")

    def test_cost_policy_picks_min_score_victim(self):
        from collections import OrderedDict
        now = 1000.0
        entries = OrderedDict([
            ("hot", _fake_entry(now, hits=20, idle=1.0, cost_ms=90.0)),
            ("mid", _fake_entry(now, hits=2, idle=100.0, cost_ms=5.0)),
            ("stale", _fake_entry(now, hits=0, idle=7200.0, cost_ms=0.0,
                                  nbytes=10_000_000)),
        ])
        assert storage_policy.CostPolicy().victim(entries, now) == "stale"
        assert storage_policy.LruPolicy().victim(entries, now) == "hot"


# ----------------------------------------------------------- tiered cache


class TestTieredCache:
    def test_demote_promote_bit_identical(self, env, tmp_path):
        wl, canon, backend = env
        qt = year_queries(canon, backend)
        nb = qt[0][1].nbytes()
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cache = fresh_cache(wl, capacity_bytes=int(nb * 2.5), policy="cost")
        cache.attach_store(store)
        for s, t in qt:
            cache.put(s, t, cost_ms=5.0)
        assert cache.stats.demotions > 0
        assert len(cache.cold_keys()) > 0
        for s, t in qt:
            lr = cache.lookup(s)
            assert lr.status == "hit_exact"
            assert lr.table.equals(t)
        assert cache.stats.promotions > 0
        store.close()

    def test_cold_hit_carries_tier_tag_hot_hit_does_not(self, env, tmp_path):
        wl, canon, backend = env
        (s, t), = year_queries(canon, backend, years=(1994,))
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cache = fresh_cache(wl)
        cache.attach_store(store)
        cache.put(s, t)
        assert cache.lookup(s).tier is None
        # force a demotion, then the next lookup promotes from cold
        cache.capacity_bytes = 1
        cache._enforce_capacity()
        assert s.key() in cache.cold_keys()
        cache.capacity_bytes = None
        lr = cache.lookup(s)
        assert lr.status == "hit_exact" and lr.tier == "cold"
        assert lr.table.equals(t)
        assert cache.lookup(s).tier is None  # resident again
        store.close()

    def test_differential_oracle_tiered_vs_all_hot(self, env, tmp_path):
        """Identical request stream -> identical statuses and tables, the
        only allowed difference being which tier served them."""
        wl, canon, backend = env
        stream = [q(f"d_year = {y}") for y in (1992, 1993, 1994, 1995, 1996)]
        stream += [q("d_year = 1994", group="c_region"),   # exact re-hit
                   q("d_year = 1994", group="c_nation")]   # new group
        stream += [q(f"d_year = {y}") for y in (1992, 1995, 1996)]  # re-hits
        sigs = [canon.canonicalize(sql) for sql in stream]
        nb = backend.execute(sigs[0]).nbytes()

        plain = fresh_cache(wl)
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        tiered = fresh_cache(wl, capacity_bytes=int(nb * 2.2), policy="cost")
        tiered.attach_store(store)

        for sig in sigs:
            outs = []
            for cache in (plain, tiered):
                lr = cache.lookup(sig)
                if lr.status == "miss":
                    table = backend.execute(sig)
                    cache.put(sig, table, cost_ms=3.0)
                else:
                    table = lr.table
                outs.append((("miss" if lr.status == "miss" else lr.status),
                             table))
            assert outs[0][0] == outs[1][0], f"status diverged on {sig.key()}"
            assert outs[0][1].equals(outs[1][1]), f"table diverged on {sig.key()}"
        assert tiered.stats.demotions > 0  # the budget actually bit
        store.close()

    def test_lru_policy_differential_without_store_matches_legacy(self, env):
        """policy='lru' with no store is the pre-tiering evictor: same
        victims, same statuses."""
        wl, canon, backend = env
        qt = year_queries(canon, backend, years=(1992, 1993, 1994))
        legacy = fresh_cache(wl, capacity=2)
        lru = fresh_cache(wl, capacity=2, policy="lru")
        for s, t in qt:
            legacy.put(s, t)
            lru.put(s, t)
        for s, _ in qt:
            assert legacy.lookup(s).status == lru.lookup(s).status

    def test_ttl_expiry_counted_and_lazy(self, env):
        wl, canon, backend = env
        (s, t), = year_queries(canon, backend, years=(1994,))
        cache = fresh_cache(wl)
        cache.put(s, t, ttl_s=0.02)
        assert cache.lookup(s).status == "hit_exact"
        time.sleep(0.05)
        assert cache.lookup(s).status == "miss"
        assert cache.stats.ttl_expiries == 1
        assert s.key() not in cache._entries

    def test_entries_summary_exposes_policy_inputs(self, env):
        wl, canon, backend = env
        qt = year_queries(canon, backend, years=(1994, 1995))
        cache = fresh_cache(wl)
        for s, t in qt:
            cache.put(s, t, cost_ms=7.0)
        cache.lookup(qt[0][0])
        rows = cache.entries_summary()
        assert len(rows) == 2
        for row in rows:
            for field in ("key", "tier", "age_s", "idle_s", "hits",
                          "decayed_hits", "cost_ms", "nbytes", "score",
                          "version"):
                assert field in row
        assert {r["tier"] for r in rows} == {"hot"}
        assert all(r["cost_ms"] == 7.0 for r in rows)

    def test_tier_stats_shape(self, env, tmp_path):
        wl, canon, backend = env
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cache = fresh_cache(wl)
        cache.attach_store(store)
        ts = cache.tier_stats()
        for field in ("hot_entries", "cold_entries", "hot_bytes", "cold_bytes",
                      "promotions", "demotions", "cold_drops", "ttl_expiries",
                      "policy", "store"):
            assert field in ts
        assert ts["store"]["spill_queue_depth"] == 0
        store.close()


# ----------------------------------------------------------- warm restart


class TestWarmRestart:
    def test_save_load_shims_still_roundtrip(self, env, tmp_path):
        wl, canon, backend = env
        qt = year_queries(canon, backend)
        cache = fresh_cache(wl)
        for s, t in qt:
            cache.put(s, t)
        spill = str(tmp_path / "spill")
        assert save_cache(cache, spill) == len(qt)
        warm = fresh_cache(wl)
        assert load_cache(warm, spill) == len(qt)
        for s, t in qt:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)

    def test_restart_restores_stamps_and_eviction_order(self, env, tmp_path):
        """Satellite: persisted lru/store stamps reconstruct recency order
        deterministically — the warm cache evicts the same victim the
        original would have."""
        wl, canon, backend = env
        qt = year_queries(canon, backend, years=(1992, 1993, 1994))
        cache = fresh_cache(wl, capacity=3)
        for s, t in qt:
            cache.put(s, t)
        cache.lookup(qt[0][0])  # 1992 is now MRU; 1993 is LRU
        spill = str(tmp_path / "spill")
        save_cache(cache, spill)
        orig_stamps = {k: (e.lru_stamp, e.store_stamp)
                       for k, e in cache._entries.items()}

        warm = fresh_cache(wl, capacity=3)
        load_cache(warm, spill)
        for k, stamps in orig_stamps.items():
            e = warm.entry(k)
            assert (e.lru_stamp, e.store_stamp) == stamps
        assert list(warm._entries) == list(cache._entries)
        extra = canon.canonicalize(q("d_year = 1996"))
        warm.put(extra, backend.execute(extra))
        assert warm.lookup(qt[1][0]).status == "miss"      # 1993 evicted
        assert warm.lookup(qt[0][0]).status == "hit_exact"  # 1992 survived

    def test_new_stamps_stay_above_restored_ones(self, env, tmp_path):
        wl, canon, backend = env
        qt = year_queries(canon, backend, years=(1994, 1995))
        cache = fresh_cache(wl)
        for s, t in qt:
            cache.put(s, t)
        spill = str(tmp_path / "spill")
        save_cache(cache, spill)
        warm = fresh_cache(wl)
        load_cache(warm, spill)
        restored_max = max(e.lru_stamp for e in warm._entries.values())
        extra = canon.canonicalize(q("d_year = 1996"))
        warm.put(extra, backend.execute(extra))
        assert warm.entry(extra.key()).lru_stamp > restored_max

    def test_incremental_save_rewrites_no_clean_payloads(self, env, tmp_path):
        """Satellite: a second save of an unchanged cache appends metadata
        records only — payload files are not rewritten."""
        wl, canon, backend = env
        qt = year_queries(canon, backend)
        cache = fresh_cache(wl)
        for s, t in qt:
            cache.put(s, t)
        spill = str(tmp_path / "spill")
        save_cache(cache, spill)
        mtimes = {f: os.stat(os.path.join(spill, f)).st_mtime_ns
                  for f in os.listdir(spill) if f.endswith(".npz")}
        assert len(mtimes) == len(qt)
        save_cache(cache, spill)
        after = {f: os.stat(os.path.join(spill, f)).st_mtime_ns
                 for f in os.listdir(spill) if f.endswith(".npz")}
        assert after == mtimes
        # a mutated entry IS rewritten
        cache.refresh_entry(qt[0][0].key(), qt[0][1], "snap1")
        save_cache(cache, spill)
        changed = {f: os.stat(os.path.join(spill, f)).st_mtime_ns
                   for f in os.listdir(spill) if f.endswith(".npz")}
        assert sum(changed[f] != mtimes[f] for f in mtimes) == 1

    def test_attached_store_write_behind_then_restart(self, env, tmp_path):
        """Write-through + async spill: the durable copy survives an
        ungraceful stop (no close/compact — WAL only)."""
        wl, canon, backend = env
        qt = year_queries(canon, backend)
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cache = fresh_cache(wl, write_through=True)
        cache.attach_store(store)
        for s, t in qt:
            cache.put(s, t, cost_ms=2.0)
        assert store.flush()
        # "kill": abandon cache + store without close()  (log not compacted)
        store2 = TieredStore(str(tmp_path / "store"))
        adopted = store2.open()
        assert len(adopted) == len(qt)
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        for s, t in qt:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.tier == "cold"
            assert lr.table.equals(t)
        store2.close()


# ----------------------------------------------------------- crash safety


class TestCrashSafety:
    def _persisted(self, env, tmp_path, n_years=4):
        wl, canon, backend = env
        years = (1992, 1993, 1994, 1995)[:n_years]
        qt = year_queries(canon, backend, years=years)
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cache = fresh_cache(wl, write_through=True)
        cache.attach_store(store)
        for s, t in qt:
            cache.put(s, t)
        store.flush()
        store.close()
        return qt, str(tmp_path / "store")

    def test_truncated_payload_is_a_miss_not_a_false_hit(self, env, tmp_path):
        qt, root = self._persisted(env, tmp_path)
        victim = payload_name(qt[0][0].key())
        vpath = os.path.join(root, victim)
        data = open(vpath, "rb").read()
        with open(vpath, "wb") as f:
            f.write(data[: len(data) // 2])  # torn mid-write
        store = TieredStore(root)
        adopted = store.open()
        # size framing drops the torn record at replay; payload deleted
        assert len(adopted) == len(qt) - 1
        assert store.replay_report["missing_payloads"] == 1
        wl, canon, backend = env
        warm = fresh_cache(wl)
        warm.attach_store(store, entries=adopted)
        assert warm.lookup(qt[0][0]).status == "miss"
        for s, t in qt[1:]:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)
        store.close()

    def test_same_size_corruption_fails_sha_and_misses(self, env, tmp_path):
        qt, root = self._persisted(env, tmp_path)
        victim = payload_name(qt[0][0].key())
        vpath = os.path.join(root, victim)
        data = bytearray(open(vpath, "rb").read())
        data[len(data) // 2] ^= 0xFF
        with open(vpath, "wb") as f:
            f.write(bytes(data))
        store = TieredStore(root)
        adopted = store.open()
        assert len(adopted) == len(qt)  # size framing can't see it
        wl, canon, backend = env
        warm = fresh_cache(wl)
        warm.attach_store(store, entries=adopted)
        lr = warm.lookup(qt[0][0])
        assert lr.status == "miss"  # sha verification refused the payload
        assert store.stats()["payload_corrupt"] == 1
        # and the damaged entry is dropped, not retried forever
        assert qt[0][0].key() not in warm.cold_keys()
        store.close()

    def test_partial_wal_record_recovers_prefix(self, env, tmp_path):
        qt, root = self._persisted(env, tmp_path)
        # post-close the manifest is compacted; write fresh WAL traffic and
        # tear the last record mid-line
        store = TieredStore(root)
        adopted = store.open()
        assert len(adopted) == len(qt)
        store.close(compact=False)
        with open(os.path.join(root, "manifest.log"), "ab") as f:
            f.write(b'{"key":"torn-record-never-finished","op":"pu')
        store2 = TieredStore(root)
        assert len(store2.open()) == len(qt)
        assert store2.stats()["torn_records"] == 1
        store2.close()

    def test_zero_false_hits_after_restart(self, env, tmp_path):
        """Everything a warm-restarted cache serves equals direct backend
        execution — the paper's zero-false-hit invariant, post-crash."""
        qt, root = self._persisted(env, tmp_path)
        wl, canon, backend = env
        store = TieredStore(root)
        warm = fresh_cache(wl)
        warm.attach_store(store, entries=store.open())
        probes = [canon.canonicalize(q(f"d_year = {y}"))
                  for y in (1992, 1993, 1994, 1995)]
        probes.append(canon.canonicalize(q("d_year = 1994", group="c_nation")))
        for sig in probes:
            lr = warm.lookup(sig)
            if lr.status != "miss":
                assert lr.table.equals(backend.execute(sig))
        store.close()

    def test_delete_tombstone_survives_restart(self, env, tmp_path):
        qt, root = self._persisted(env, tmp_path)
        store = TieredStore(root)
        adopted = store.open()
        wl, canon, backend = env
        warm = fresh_cache(wl)
        warm.attach_store(store, entries=adopted)
        assert warm.drop(qt[0][0].key())
        store.close(compact=False)  # tombstone lives in the WAL only
        store2 = TieredStore(root)
        assert len(store2.open()) == len(qt) - 1
        assert not store2.has(qt[0][0].key())
        store2.close()


# ------------------------------------------------------ service lifecycle


class TestServiceLifecycle:
    def _service(self, wl):
        from repro.service import CacheService

        backend = OlapExecutor(wl.dataset, impl="numpy")
        svc = CacheService()
        svc.register_tenant("bi", schema=wl.schema, backend=backend,
                            cache=fresh_cache(wl))
        return svc, backend

    def test_open_close_warm_restart(self, ssb_small, tmp_path):
        from repro.service import QueryRequest

        wl = ssb_small
        root = str(tmp_path / "svc-store")
        queries = [q(f"d_year = {y}") for y in (1992, 1993, 1994)]

        svc, _ = self._service(wl)
        assert svc.open(root) == {"bi": 0}
        cold_results = [svc.submit(QueryRequest(sql=sql, tenant="bi"))
                        for sql in queries]
        assert all(r.status == "miss" for r in cold_results)
        assert svc.close() == {"bi": len(queries)}

        svc2, backend2 = self._service(wl)
        adopted = svc2.open(root)
        assert adopted == {"bi": len(queries)}
        for sql, cold in zip(queries, cold_results):
            r = svc2.submit(QueryRequest(sql=sql, tenant="bi"))
            assert r.status == "hit_exact"
            assert "tier:cold" in r.provenance
            assert r.table.equals(cold.table)
        svc2.close()

    def test_stats_expose_tiers_and_entries(self, ssb_small, tmp_path):
        from repro.service import QueryRequest

        svc, _ = self._service(ssb_small)
        svc.open(str(tmp_path / "svc-store"))
        svc.submit(QueryRequest(sql=q(), tenant="bi"))
        d = svc.stats("bi")
        assert "tiers" in d
        for field in ("hot_entries", "cold_entries", "hot_bytes", "cold_bytes",
                      "promotions", "demotions", "spill_queue_depth"):
            assert field in d["tiers"], field
        assert "entries" not in d
        d2 = svc.stats("bi", include_entries=True)
        assert d2["entries"] and d2["entries"][0]["tier"] == "hot"
        json.dumps(d2["entries"])  # summary must be JSON-serializable
        svc.close()

    def test_tenant_registered_after_open_gets_a_store(self, ssb_small, tmp_path):
        from repro.service import CacheService, QueryRequest

        wl = ssb_small
        svc = CacheService()
        svc.open(str(tmp_path / "svc-store"))
        backend = OlapExecutor(wl.dataset, impl="numpy")
        svc.register_tenant("late", schema=wl.schema, backend=backend,
                            cache=fresh_cache(wl))
        svc.submit(QueryRequest(sql=q(), tenant="late"))
        svc.close()
        assert os.path.isdir(os.path.join(str(tmp_path / "svc-store"), "late"))
        svc2 = CacheService()
        svc2.register_tenant("late", schema=wl.schema, backend=backend,
                             cache=fresh_cache(wl))
        assert svc2.open(str(tmp_path / "svc-store")) == {"late": 1}
        r = svc2.submit(QueryRequest(sql=q(), tenant="late"))
        assert r.status == "hit_exact" and "tier:cold" in r.provenance
        svc2.close()


# ----------------------------------------------------------- cluster tier


class TestClusterTiered:
    def test_shared_store_and_resharding_carry_cold_entries(self, env, tmp_path):
        from repro.cluster import CacheCluster

        wl, canon, backend = env
        qt = year_queries(canon, backend)
        nb = qt[0][1].nbytes()
        cluster = CacheCluster(wl.schema, 2,
                               level_mapper=wl.dataset.level_mapper(),
                               capacity_bytes=int(nb * 3), policy="cost")
        store = TieredStore(str(tmp_path / "store"))
        store.open()
        cluster.attach_store(store)
        for s, t in qt:
            cluster.put(s, t, cost_ms=4.0)
        ts = cluster.tier_stats()
        assert ts["demotions"] > 0 and ts["cold_entries"] > 0
        for n in (3, 1):
            cluster.set_shards(n)
            for s, t in qt:
                lr = cluster.lookup(s)
                assert lr.status == "hit_exact", (n, lr.status)
                assert lr.table.equals(t)
        store.flush()
        store.close()

    def test_cluster_warm_restart_routes_by_family(self, env, tmp_path):
        from repro.cluster import CacheCluster

        wl, canon, backend = env
        qt = year_queries(canon, backend)
        root = str(tmp_path / "store")
        cluster = CacheCluster(wl.schema, 3,
                               level_mapper=wl.dataset.level_mapper(),
                               write_through=True)
        store = TieredStore(root)
        store.open()
        cluster.attach_store(store)
        for s, t in qt:
            cluster.put(s, t)
        store.flush()
        store.close()

        store2 = TieredStore(root)
        warm = CacheCluster(wl.schema, 3,
                            level_mapper=wl.dataset.level_mapper())
        adopted = warm.attach_store(store2, entries=store2.open())
        assert adopted == len(qt)
        for s, t in qt:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)
        store2.close()


# ------------------------------------------------------------ chaos harness


class TestChaosHarness:
    """Satellite: deterministic fault-injection (REPRO_FAULTS points) against
    the durable tier — WAL write failures mid-save, torn frames, payload
    corruption and transient read outages.  Every scenario must degrade to a
    miss or a retried success, never a false hit, never a lost prefix."""

    def _attached(self, env, tmp_path):
        wl, canon, backend = env
        qt = year_queries(canon, backend)
        root = str(tmp_path / "store")
        store = TieredStore(root)
        store.open()
        cache = fresh_cache(wl, write_through=True)
        cache.attach_store(store)
        return qt, root, store, cache

    def test_wal_enospc_mid_save_recovers_prefix(self, env, tmp_path):
        """Disk-full (injected ENOSPC on every WAL append) midway through a
        save: the writes before the outage survive, the writes during it are
        surfaced as spill errors — and a reopen recovers exactly the longest
        consistent prefix, bit-identical."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        for s, t in qt[:3]:
            cache.put(s, t)
        assert store.flush()
        with faults.scoped("storage.wal_enospc:1.0"):
            for s, t in qt[3:]:
                cache.put(s, t)
            assert store.flush()  # claims drained (dropped after retries)
            st = store.stats()
            assert st["spill_errors"] == 3
            assert st["spill_retries"] == 6  # two retries per failed key
            assert "storage.wal_enospc" in st["spill_last_error"]
        # the hot tier still serves everything; nothing raised
        for s, t in qt:
            lr = cache.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)
        store2 = TieredStore(root)
        adopted = store2.open()
        assert {e.signature.key() for e in adopted} == \
            {s.key() for s, _ in qt[:3]}
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        for s, t in qt[:3]:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)
        for s, _ in qt[3:]:
            assert warm.lookup(s).status == "miss"
        store2.close()

    def test_wal_oserror_is_retried_and_lands(self, env, tmp_path):
        """A transient WAL OSError (fires on the first append only — seed 19
        draws fire,clean,clean,... at rate 0.3) costs one retry; the write
        still lands durably."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        with faults.scoped("storage.wal_oserror:0.3:19"):
            cache.put(*qt[0])
            assert store.flush()
        st = store.stats()
        assert st["spill_errors"] == 0
        assert st["spill_retries"] == 1
        assert st["spilled_writes"] == 1
        store.close()
        store2 = TieredStore(root)
        assert {e.signature.key() for e in store2.open()} == {qt[0][0].key()}
        store2.close()

    def test_torn_wal_frame_skipped_on_replay(self, env, tmp_path):
        """``storage.wal_torn`` writes half a frame then raises (a kill
        mid-append): the retries exhaust, the torn garbage is skipped and
        counted at replay, and the earlier records all survive."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        for s, t in qt[:3]:
            cache.put(s, t)
        assert store.flush()
        with faults.scoped("storage.wal_torn:1.0"):
            cache.put(*qt[3])
            assert store.flush()
            assert store.stats()["spill_errors"] == 1
        store2 = TieredStore(root)
        adopted = store2.open()
        assert {e.signature.key() for e in adopted} == \
            {s.key() for s, _ in qt[:3]}
        assert store2.stats()["torn_records"] >= 1
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        for s, t in qt[:3]:
            lr = warm.lookup(s)
            assert lr.status == "hit_exact" and lr.table.equals(t)
        store2.close()

    def test_sha_corruption_under_chaos_is_miss_not_false_hit(self, env,
                                                              tmp_path):
        """``storage.sha_corrupt`` flips payload bytes at read time: the sha
        gate refuses the table — a miss, never a wrong answer — and the
        damaged entry is dropped rather than retried forever."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        for s, t in qt[:2]:
            cache.put(s, t)
        store.flush()
        store.close()
        store2 = TieredStore(root)
        adopted = store2.open()
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        with faults.scoped("storage.sha_corrupt:1.0"):
            assert warm.lookup(qt[0][0]).status == "miss"
            assert store2.stats()["payload_corrupt"] == 1
            assert qt[0][0].key() not in warm.cold_keys()
        # undamaged entries keep serving bit-identically once chaos stops
        lr = warm.lookup(qt[1][0])
        assert lr.status == "hit_exact" and lr.table.equals(qt[1][1])
        store2.close()

    def test_transient_read_error_is_retried(self, env, tmp_path):
        """One injected cold-read IO error (seed 12: fire,clean,... at rate
        0.3) is absorbed by the peek micro-retry — the lookup still hits."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        cache.put(*qt[0])
        store.flush()
        store.close()
        store2 = TieredStore(root)
        adopted = store2.open()
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        with faults.scoped("coldtier.read_error:0.3:12"):
            lr = warm.lookup(qt[0][0])
        assert lr.status == "hit_exact" and lr.table.equals(qt[0][1])
        st = store2.stats()
        assert st["read_errors"] == 1
        assert st["cold_breaker"]["state"] == "closed"
        store2.close()

    def test_cold_outage_opens_breaker_then_recovers(self, env, tmp_path):
        """A sustained cold-tier outage: reads exhaust their retries, the
        breaker opens (then fails fast, no disk churn), and — crucially — the
        cold entries are *kept*, so after the recovery window a half-open
        probe succeeds, the breaker closes, and the same key serves again."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        cache.put(*qt[0])
        store.flush()
        store.close()
        store2 = TieredStore(root)
        store2.cold_breaker.recovery_s = 0.1
        adopted = store2.open()
        warm = fresh_cache(wl)
        warm.attach_store(store2, entries=adopted)
        with faults.scoped("coldtier.read_error:1.0"):
            for _ in range(5):  # failure_threshold: 5 exhausted reads
                assert warm.lookup(qt[0][0]).status == "miss"
            st = store2.stats()
            assert st["cold_breaker"]["state"] == "open"
            assert st["read_errors"] == 15  # 3 attempts x 5 reads
            # open breaker fails fast: the next miss touches no disk
            assert warm.lookup(qt[0][0]).status == "miss"
            st = store2.stats()
            assert st["read_errors"] == 15
            assert st["cold_breaker"]["rejections"] >= 1
            # the replica was never dropped during the outage
            assert qt[0][0].key() in warm.cold_keys()
        time.sleep(0.15)  # recovery window, chaos over
        lr = warm.lookup(qt[0][0])
        assert lr.status == "hit_exact" and lr.table.equals(qt[0][1])
        assert store2.stats()["cold_breaker"]["state"] == "closed"
        store2.close()

    def test_spill_worker_death_never_loses_the_write(self, env, tmp_path):
        """``storage.spill_death`` kills the async spill worker mid-shift
        (seed 132: first dequeue only).  The claim is requeued, flush()
        restarts the worker, and every write lands durably."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        with faults.scoped("storage.spill_death:0.3:132"):
            for s, t in qt[:2]:
                cache.put(s, t)
            assert store.flush()
        st = store.stats()
        assert st["worker_deaths"] == 1
        assert st["spill_errors"] == 0
        store.close()
        store2 = TieredStore(root)
        assert {e.signature.key() for e in store2.open()} == \
            {s.key() for s, _ in qt[:2]}
        store2.close()

    def test_spill_error_retry_then_exhaustion_surfaced(self, env, tmp_path):
        """``storage.spill_error`` at the payload-write boundary: a single
        transient fault (seed 4) is retried and lands; a hard outage (rate
        1.0) is surfaced in spill_errors/spill_last_error and tier_stats —
        never silently swallowed."""
        wl, canon, backend = env
        qt, root, store, cache = self._attached(env, tmp_path)
        with faults.scoped("storage.spill_error:0.3:4"):
            cache.put(*qt[0])
            assert store.flush()
        assert store.stats()["spill_retries"] == 1
        assert store.stats()["spill_errors"] == 0
        with faults.scoped("storage.spill_error:1.0"):
            cache.put(*qt[1])
            assert store.flush()
        st = store.stats()
        assert st["spill_errors"] == 1
        assert "storage.spill_error" in st["spill_last_error"]
        ts = cache.tier_stats()
        assert ts["store"]["spill_errors"] == 1
        assert "storage.spill_error" in ts["store"]["spill_last_error"]
        store.close()
        store2 = TieredStore(root)
        assert {e.signature.key() for e in store2.open()} == {qt[0][0].key()}
        store2.close()
