"""Resilience plane: the deterministic chaos harness, the primitives
(deadlines, backoff, circuit breakers), and the pipeline's containment +
graceful-degradation contract — structured degraded/error results, stale
serving under explicit provenance, breaker fail-fast and recovery, and the
service health surface.  Every injected failure here is replayable from its
spec string alone."""
import time

import pytest

from repro.core import SemanticCache
from repro.olap.executor import OlapExecutor
from repro.resilience import (CircuitBreaker, Deadline, ResiliencePolicy,
                              backoff_delays, faults)
from repro.resilience.errors import classify
from repro.resilience.faults import FaultError, FaultPlan, FaultSpec
from repro.resilience.primitives import run_with_retry
from repro.service import CacheService, QueryRequest

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")


def sql_region(measures="SUM(lo_revenue) AS r", where=""):
    w = f"WHERE {where} " if where else ""
    return (f"SELECT c_region, {measures} "
            f"FROM lineorder {JOINS}{w}GROUP BY c_region")


def mk_service(wl, *, policy=None, ttl_s=None, backend=None):
    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema,
        backend=backend or OlapExecutor(wl.dataset, impl="numpy"),
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(),
                            ttl_s=ttl_s),
        resilience=policy)
    return svc


# ------------------------------------------------------------ chaos harness


class TestFaults:
    def test_parse_specs(self):
        specs = faults.parse("backend.error:0.1, storage.*:10%:7")
        assert specs == (FaultSpec("backend.error", 0.1, 0),
                         FaultSpec("storage.*", 0.1, 7))
        with pytest.raises(ValueError):
            faults.parse("backend.error")
        with pytest.raises(ValueError):
            faults.parse("backend.error:1.5")

    def test_prefix_match(self):
        spec = FaultSpec("storage.*", 1.0)
        assert spec.matches("storage.wal_enospc")
        assert not spec.matches("backend.error")

    def test_draws_are_deterministic_and_rate_accurate(self):
        a = FaultPlan(faults.parse("p:0.1:42"))
        b = FaultPlan(faults.parse("p:0.1:42"))
        seq_a = [a.should_fire("p") for _ in range(2000)]
        seq_b = [b.should_fire("p") for _ in range(2000)]
        assert seq_a == seq_b  # counter-based: bit-for-bit replayable
        fired = sum(seq_a)
        assert 140 <= fired <= 260  # ~10% of 2000
        c = FaultPlan(faults.parse("p:0.1:43"))
        assert [c.should_fire("p") for _ in range(2000)] != seq_a

    def test_rate_edges(self):
        always = FaultPlan(faults.parse("p:1.0"))
        never = FaultPlan(faults.parse("p:0.0"))
        assert all(always.should_fire("p") for _ in range(50))
        assert not any(never.should_fire("p") for _ in range(50))

    def test_scoped_install_and_counts(self):
        with faults.scoped("x.y:1.0") as plan:
            assert faults.active_plan() is plan
            with pytest.raises(FaultError) as ei:
                faults.fire("x.y")
            assert ei.value.point == "x.y"
            assert faults.counts()["fired"]["x.y"] == 1
        assert not faults.should_fire("x.y")  # cleared on exit

    def test_env_var_activation(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "env.point:1.0")
        assert faults.should_fire("env.point")
        monkeypatch.setenv("REPRO_FAULTS", "")
        assert not faults.should_fire("env.point")

    def test_classify(self):
        assert classify(FaultError("canonicalize.timeout")) == "timeout"
        assert classify(FaultError("backend.error")) == "fault"
        assert classify(TimeoutError()) == "timeout"
        assert classify(OSError()) == "io"
        assert classify(RuntimeError()) == "error"


# -------------------------------------------------------------- primitives


class TestPrimitives:
    def test_backoff_deterministic_bounded(self):
        d1 = backoff_delays(4, 0.01, 0.25, salt="k")
        d2 = backoff_delays(4, 0.01, 0.25, salt="k")
        assert d1 == d2 and len(d1) == 3
        for i, d in enumerate(d1):
            base = min(0.25, 0.01 * 2 ** i)
            assert 0.5 * base <= d < 1.5 * base
        assert backoff_delays(4, 0.01, 0.25, salt="other") != d1
        assert backoff_delays(1, 0.01, 0.25) == []

    def test_deadline(self):
        d = Deadline.after_ms(60_000)
        assert not d.expired and d.remaining_s() > 59
        assert Deadline.after_ms(-1).expired

    def test_run_with_retry(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")
            return "ok"

        result, retries, err = run_with_retry(
            flaky, attempts=4, base_s=0.0, max_s=0.0, sleep=lambda _t: None)
        assert result == "ok" and retries == 2 and err is None
        result, retries, err = run_with_retry(
            lambda: 1 / 0, attempts=2, base_s=0.0, max_s=0.0,
            sleep=lambda _t: None)
        assert result is None and isinstance(err, ZeroDivisionError)

    def test_breaker_state_machine(self):
        clock = [0.0]
        br = CircuitBreaker("dep", failure_threshold=3, recovery_s=1.0,
                            half_open_probes=1, clock=lambda: clock[0])
        assert br.state == "closed" and br.allow()
        for _ in range(3):
            br.record_failure()
        assert br.state == "open"
        assert not br.allow()  # rejected while the window is fresh
        clock[0] = 1.5  # recovery elapsed: one probe admitted
        assert br.allow()
        assert br.state == "half_open"
        assert not br.allow()  # probe budget spent
        br.record_failure()  # failed probe re-opens with a fresh window
        assert br.state == "open" and not br.allow()
        clock[0] = 3.0
        assert br.allow()
        br.record_success()
        assert br.state == "closed" and br.allow()
        snap = br.snapshot()
        assert snap["opens"] == 2 and snap["closes"] == 1
        assert snap["rejections"] >= 2


# ------------------------------------------------- pipeline containment


class TestPipelineContainment:
    def test_backend_error_is_structured_not_raised(self, ssb_small):
        svc = mk_service(ssb_small,
                         policy=ResiliencePolicy(execute_attempts=1))
        with faults.scoped("backend.error:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "error" and not res.ok
        assert res.table is None
        assert res.error is not None
        assert res.error.stage == "execute" and res.error.kind == "fault"
        assert "failure:execute:fault" in res.provenance
        assert res.to_dict()["error"]["stage"] == "execute"
        t = svc.tenant("t")
        assert t.stats.failures == 1

    def test_retry_recovers_transient_fault(self, ssb_small):
        # ~half the execute attempts fail; three tries per request make the
        # workload succeed, with retries surfaced in provenance + stats
        svc = mk_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=3, retry_base_s=0.001, retry_max_s=0.002))
        # seed 9: every request clears within its 3-attempt budget, and at
        # least one needs a retry (the draw sequence is deterministic)
        with faults.scoped("backend.error:0.5:9"):
            results = [svc.submit(QueryRequest(
                sql=sql_region(where=f"d_year = {1992 + i}"), tenant="t"))
                for i in range(6)]
        assert all(r.status == "miss" for r in results)
        t = svc.tenant("t")
        assert t.stats.backend_executions == 6
        assert t.stats.retries >= 1
        assert any(p.startswith("retry:")
                   for r in results for p in r.provenance)

    def test_degraded_serves_stale_with_explicit_tag(self, ssb_small):
        svc = mk_service(ssb_small, ttl_s=0.05)
        fresh = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert fresh.status == "miss"
        time.sleep(0.08)  # TTL out the entry
        with faults.scoped("backend.error:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "degraded" and res.ok
        assert res.table is not None and res.table.equals(fresh.table)
        assert "degraded:stale" in res.provenance
        assert res.error is not None and res.error.degraded
        t = svc.tenant("t")
        assert t.stats.degraded == 1 and t.stats.failures == 0

    def test_stale_serving_disabled_yields_error(self, ssb_small):
        svc = mk_service(ssb_small, ttl_s=0.05,
                         policy=ResiliencePolicy(execute_attempts=1,
                                                 serve_stale=False))
        assert svc.submit(QueryRequest(sql=sql_region(),
                                       tenant="t")).status == "miss"
        time.sleep(0.08)
        with faults.scoped("backend.error:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "error" and res.table is None

    def test_deadline_shed(self, ssb_small):
        svc = mk_service(ssb_small)
        res = svc.submit(QueryRequest(sql=sql_region(), tenant="t",
                                      deadline_ms=-1.0))
        assert res.status == "error"
        assert res.error.kind == "deadline"
        assert svc.tenant("t").stats.shed == 1
        # a generous deadline changes nothing
        ok = svc.submit(QueryRequest(sql=sql_region(), tenant="t",
                                     deadline_ms=60_000.0))
        assert ok.status == "miss" and ok.table is not None

    def test_resilience_disabled_still_contains(self, ssb_small):
        svc = mk_service(ssb_small, policy=ResiliencePolicy.disabled())
        with faults.scoped("backend.error:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "error" and res.error is not None
        assert res.error.retries == 0  # no recovery machinery ran

    def test_backend_breaker_opens_and_recovers(self, ssb_small):
        svc = mk_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=1, breaker_failures=2, breaker_recovery_s=0.05))
        t = svc.tenant("t")
        with faults.scoped("backend.error:1.0"):
            for i in range(3):
                res = svc.submit(QueryRequest(
                    sql=sql_region(f"SUM(lo_revenue) AS r{i}"), tenant="t"))
                assert res.status == "error"
        # third request failed fast on the open breaker
        assert res.error.kind == "breaker_open"
        assert "breaker:open" in res.provenance
        assert t.resilience.backend.state == "open"
        time.sleep(0.08)  # recovery window elapses; faults cleared: probe ok
        res = svc.submit(QueryRequest(sql=sql_region("COUNT(*) AS n"),
                                      tenant="t"))
        assert res.status == "miss" and res.table is not None
        assert t.resilience.backend.state == "closed"
        assert t.resilience.backend.snapshot()["closes"] == 1

    def test_partial_partition_failure_fails_whole_batch_result(self, ssb_small):
        be = OlapExecutor(ssb_small.dataset, impl="numpy", partitions=2)
        svc = mk_service(ssb_small, backend=be,
                         policy=ResiliencePolicy(execute_attempts=1))
        with faults.scoped("backend.partial:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        # one partition died: no merged-over-missing-partials wrong answer
        assert res.status == "error" and res.table is None

    def test_store_failure_keeps_result(self, ssb_small, monkeypatch):
        svc = mk_service(ssb_small)
        t = svc.tenant("t")

        def boom(*a, **kw):
            raise OSError("disk gone")

        monkeypatch.setattr(t.cache, "put", boom)
        res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "miss" and res.table is not None
        assert "store:error" in res.provenance
        assert t.stats.store_errors == 1


class TestCanonicalizeFaults:
    def _nl_service(self, ssb_small, **kw):
        from repro.core import MemoizedNL, SimulatedLLM

        svc = CacheService()
        svc.register_tenant(
            "t", schema=ssb_small.schema,
            backend=OlapExecutor(ssb_small.dataset, impl="numpy"),
            nl=MemoizedNL(SimulatedLLM(ssb_small.schema)), **kw)
        return svc

    def test_timeout_fault_is_structured(self, ssb_small):
        svc = self._nl_service(ssb_small)
        with faults.scoped("canonicalize.timeout:1.0"):
            res = svc.submit(QueryRequest(
                nl="total revenue by region", tenant="t"))
        assert res.status == "error"
        assert res.error.stage == "canonicalize"
        assert res.error.kind == "timeout"

    def test_garbage_fault_bypasses_never_caches(self, ssb_small):
        svc = self._nl_service(ssb_small)
        with faults.scoped("canonicalize.garbage:1.0"):
            res = svc.submit(QueryRequest(
                nl="total revenue by region", tenant="t"))
        # garbage output loses the signature: safe bypass, nothing cached
        assert res.status == "bypass"
        assert len(svc.tenant("t").cache) == 0

    def test_lowconf_fault_gates_request(self, ssb_small):
        svc = self._nl_service(ssb_small)
        with faults.scoped("canonicalize.lowconf:1.0"):
            res = svc.submit(QueryRequest(
                nl="total revenue by region", tenant="t"))
        # 0.01 confidence is under every acceptance threshold: gated to a
        # bypass that still executes but never touches the cache
        assert res.status == "bypass"
        assert res.confidence == 0.01
        assert len(svc.tenant("t").cache) == 0

    def test_canonicalizer_breaker_opens(self, ssb_small):
        svc = self._nl_service(
            ssb_small, resilience=ResiliencePolicy(breaker_failures=2,
                                                   breaker_recovery_s=60.0))
        with faults.scoped("canonicalize.timeout:1.0"):
            for _ in range(2):
                svc.submit(QueryRequest(nl="revenue by region", tenant="t"))
        res = svc.submit(QueryRequest(nl="revenue by region", tenant="t"))
        assert res.status == "error"
        assert res.error.kind == "breaker_open"
        assert svc.tenant("t").resilience.canonicalizer.state == "open"


# ------------------------------------------------------------ health surface


class TestHealth:
    def test_health_ok_then_degraded(self, ssb_small):
        svc = mk_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=1, breaker_failures=1, serve_stale=False))
        h = svc.health("t")
        assert h["status"] == "ok" and h["open_breakers"] == []
        assert set(h["breakers"]) == {"canonicalizer", "backend"}
        with faults.scoped("backend.error:1.0"):
            svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        h = svc.health("t")
        assert h["status"] == "degraded"
        assert "backend" in h["open_breakers"]
        assert h["counters"]["failures"] == 1
        # the all-tenants form nests per tenant
        assert svc.health()["t"]["status"] == "degraded"

    def test_health_includes_storage_counters(self, ssb_small, tmp_path):
        svc = mk_service(ssb_small)
        svc.open(str(tmp_path))
        try:
            svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
            h = svc.health("t")
            assert "cold_tier" in h["breakers"]
            assert "spill_errors" in h["storage"]
            assert h["storage"]["spill_last_error"] is None
        finally:
            svc.close()


# ------------------------------------------------- no-exception-escape sweep


class TestNoEscape:
    @pytest.mark.parametrize("spec", [
        "backend.error:1.0",
        "canonicalize.timeout:1.0",
        "canonicalize.garbage:1.0",
        "backend.error:0.25:11,canonicalize.timeout:0.25:12",
    ])
    def test_mixed_workload_never_raises(self, ssb_small, spec):
        from repro.core import MemoizedNL, SimulatedLLM

        svc = CacheService()
        svc.register_tenant(
            "t", schema=ssb_small.schema,
            backend=OlapExecutor(ssb_small.dataset, impl="numpy"),
            nl=MemoizedNL(SimulatedLLM(ssb_small.schema)),
            resilience=ResiliencePolicy(execute_attempts=2,
                                        retry_base_s=0.001,
                                        retry_max_s=0.002))
        reqs = []
        for i in range(4):
            reqs.append(QueryRequest(
                sql=sql_region(f"SUM(lo_revenue) AS r{i}"), tenant="t"))
            reqs.append(QueryRequest(nl="total revenue by region",
                                     tenant="t"))
        with faults.scoped(spec):
            results = svc.submit_batch(reqs)
        for r in results:
            assert r.status in ("miss", "hit_exact", "hit_rollup",
                                "hit_filterdown", "bypass", "degraded",
                                "error")
            if r.status == "error":
                assert r.error is not None and r.table is None


# ------------------------------------------- chaos outcomes on request traces


class TestChaosSpans:
    """Resilience outcomes must be visible on the request's trace: a retried
    execute carries its retry count, a breaker fail-fast names the breaker
    state, a degraded serve is flagged on the failing stage's span — and
    under a mixed fault plan every traced result still has a span for every
    stage its provenance proves it passed through."""

    def _obs_service(self, wl, *, policy=None, ttl_s=None):
        from repro.obs import ObsConfig

        svc = CacheService(obs=ObsConfig.full(sample_rate=1.0))
        svc.register_tenant(
            "t", schema=wl.schema,
            backend=OlapExecutor(wl.dataset, impl="numpy"),
            cache=SemanticCache(wl.schema,
                                level_mapper=wl.dataset.level_mapper(),
                                ttl_s=ttl_s),
            resilience=policy)
        return svc

    def _stage_span(self, svc, res, stage):
        spans = [s for s in svc.obs.tracer.spans(res.trace_id)
                 if s["name"] == stage]
        assert spans, f"no {stage} span on trace {res.trace_id}"
        return spans[0]

    def test_retry_count_lands_on_execute_span(self, ssb_small):
        svc = self._obs_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=3, retry_base_s=0.001, retry_max_s=0.002))
        with faults.scoped("backend.error:0.5:9"):
            results = [svc.submit(QueryRequest(
                sql=sql_region(where=f"d_year = {1992 + i}"), tenant="t"))
                for i in range(6)]
        assert all(r.status == "miss" for r in results)
        retried = [r for r in results
                   if any(p.startswith("retry:") for p in r.provenance)]
        assert retried  # seed 9: at least one request needed a retry
        for r in retried:
            n = next(int(p.split(":", 1)[1]) for p in r.provenance
                     if p.startswith("retry:"))
            # both the finalize-time stage span and the live backend span
            # carry the count
            assert self._stage_span(svc, r, "execute")["attrs"][
                "retries"] == n
            assert self._stage_span(svc, r, "execute.backend")["attrs"][
                "retries"] == n

    def test_breaker_fail_fast_named_on_error_span(self, ssb_small):
        svc = self._obs_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=1, breaker_failures=2, breaker_recovery_s=60.0))
        with faults.scoped("backend.error:1.0"):
            for i in range(3):
                res = svc.submit(QueryRequest(
                    sql=sql_region(f"SUM(lo_revenue) AS r{i}"), tenant="t"))
        assert res.error.kind == "breaker_open"
        span = self._stage_span(svc, res, "execute")
        assert span["attrs"]["failure_kind"] == "breaker_open"
        assert span["attrs"]["breaker"] == "open"
        assert span["attrs"]["degraded"] is False
        root = self._stage_span(svc, res, "request")
        assert "breaker:open" in root["attrs"]["events"]

    def test_degraded_serve_flagged_on_span(self, ssb_small):
        svc = self._obs_service(ssb_small, ttl_s=0.05)
        assert svc.submit(QueryRequest(sql=sql_region(),
                                       tenant="t")).status == "miss"
        time.sleep(0.08)  # TTL out the entry
        with faults.scoped("backend.error:1.0"):
            res = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert res.status == "degraded"
        span = self._stage_span(svc, res, "execute")
        assert span["attrs"]["degraded"] is True
        assert span["attrs"]["failure_kind"] == "fault"
        assert "degraded:stale" in self._stage_span(
            svc, res, "request")["attrs"]["events"]

    def test_chaos_traces_stay_complete(self, ssb_small):
        from repro.obs import trace_completeness

        svc = self._obs_service(ssb_small, policy=ResiliencePolicy(
            execute_attempts=2, retry_base_s=0.001, retry_max_s=0.002))
        reqs = [QueryRequest(sql=sql_region(where=f"d_year = {1992 + i % 4}"),
                             tenant="t") for i in range(12)]
        with faults.scoped("backend.error:0.25:11,"
                           "canonicalize.timeout:0.25:12"):
            results = svc.submit_batch(reqs)
        comp = trace_completeness(results, svc.obs.tracer)
        assert comp["traces_checked"] == len(results)
        assert comp["ok"], comp["missing"]
