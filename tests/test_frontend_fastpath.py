"""Request-plane fast path (ISSUE 4): parameterized template cache,
interned signature keys, and indexed derivation probes.

The load-bearing invariants:

* template-rebound canonicalization is **bit-identical** to cold-parse
  canonicalization (same canonical JSON, same key) over workload renders and
  randomized literals — property-tested;
* two texts sharing a template but differing in literals never collide
  (cache-poisoning guard);
* one request computes the SHA-256 signature key at most once (counting
  hook), and memoized repeats compute it zero times;
* the indexed derivation probe attempts plans on a bounded, structurally
  viable candidate subset with hit/miss outcomes identical to the pre-index
  linear scan.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import signature as sigmod
from repro.core import sqlparse as sp
from repro.core.cache import SemanticCache
from repro.core.signature import Filter, Measure, Signature, TimeWindow
from repro.core.sql_canon import SQLCanonicalizer
from repro.core.table import ResultTable
from repro.workloads.variants import make_variants

_JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")


def _tile_sql(region: str, qty, year: int, upper: bool = False) -> str:
    sql = ("SELECT c_region, SUM(lo_revenue) AS rev, COUNT(*) AS n "
           f"FROM lineorder {_JOINS}"
           f"WHERE c_region = '{region}' AND lo_quantity < {qty} "
           f"AND d_year = {year} GROUP BY c_region")
    return sql.upper().replace(f"'{region.upper()}'", f"'{region}'") if upper else sql


# ------------------------------------------------------------ template cache


class TestTemplateCache:
    def test_warm_equals_cold_all_workloads(self, ssb_small, tlc_small, tpcds_small):
        """Every workload query, canonicalized twice through a warm template
        cache, matches a cold-parse canonicalizer bit for bit."""
        for wl in (ssb_small, tlc_small, tpcds_small):
            fast = SQLCanonicalizer(wl.schema)
            cold = SQLCanonicalizer(wl.schema, template_cache=False)
            for i, intent in enumerate(wl.intents):
                for v in make_variants(intent.sql, wl.schema, n=7, seed=i):
                    a = fast.canonicalize(v)  # first arrival of this text
                    b = fast.canonicalize(v)  # verbatim repeat: text memo hit
                    c = cold.canonicalize(v)
                    assert a.canonical_json() == c.canonical_json()
                    assert b is a  # interned instance on memo hit
            assert fast.template_stats()["text_hits"] > 0

    def test_rebind_fresh_literals_equals_cold(self, ssb_small):
        fast = SQLCanonicalizer(ssb_small.schema)
        cold = SQLCanonicalizer(ssb_small.schema, template_cache=False)
        fast.canonicalize(_tile_sql("ASIA", 25, 1994))  # warms the template
        sql2 = _tile_sql("EUROPE", 30, 1997)
        assert fast.canonicalize(sql2).canonical_json() == \
            cold.canonicalize(sql2).canonical_json()
        assert fast.template_stats()["template_hits"] == 1

    def test_same_template_different_literals_no_collision(self, ssb_small):
        """Cache-poisoning guard: the binding memo is keyed by the full
        literal tuple, so same-template texts keep distinct signatures."""
        fast = SQLCanonicalizer(ssb_small.schema)
        a = fast.canonicalize(_tile_sql("ASIA", 25, 1994))
        b = fast.canonicalize(_tile_sql("ASIA", 26, 1994))
        c = fast.canonicalize(_tile_sql("EUROPE", 25, 1994))
        assert len({a.key(), b.key(), c.key()}) == 3
        f = {x for s in (a, b, c) for x in s.filters if "quantity" in x.col}
        assert {x.val for x in f} == {25, 26}

    def test_scope_partitions_binding_memo(self, ssb_small):
        fast = SQLCanonicalizer(ssb_small.schema)
        sql = _tile_sql("ASIA", 25, 1994)
        a = fast.canonicalize(sql, scope="t1")
        b = fast.canonicalize(sql, scope="t2")
        assert a.key() != b.key() and a.scope == "t1" and b.scope == "t2"

    def test_value_dependent_canonicalization_not_poisoned(self, ssb_small):
        """Whether a literal folds into a time window depends on its value;
        two bindings of one template must each get the cold-path answer."""
        fast = SQLCanonicalizer(ssb_small.schema)
        cold = SQLCanonicalizer(ssb_small.schema, template_cache=False)
        base = ("SELECT SUM(lo_revenue) r FROM lineorder "
                "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
                "WHERE d_yearmonth = '{v}'")
        folds = base.format(v="Mar1994")   # folds to a month window
        stays = base.format(v="notamonth")  # stays an ordinary filter
        for sql in (folds, stays):
            assert fast.canonicalize(sql).canonical_json() == \
                cold.canonicalize(sql).canonical_json()
        assert fast.canonicalize(folds).time_window is not None
        assert fast.canonicalize(stays).time_window is None

    def test_errors_raise_identically_warm_and_cold(self, ssb_small):
        from repro.core.sql_canon import CanonicalizationError

        fast = SQLCanonicalizer(ssb_small.schema)
        bad = ("SELECT SUM(nonexistent_col) FROM lineorder "
               "WHERE lo_quantity < {q}")
        for q in (5, 6):  # second arrival exercises the warm-template path
            with pytest.raises(CanonicalizationError):
                fast.canonicalize(bad.format(q=q))
        with pytest.raises(sp.UnsupportedQuery):
            fast.canonicalize("SELECT lo_revenue FROM lineorder")

    def test_keyword_case_and_whitespace_share_template(self, ssb_small):
        fast = SQLCanonicalizer(ssb_small.schema)
        fast.canonicalize(_tile_sql("ASIA", 25, 1994))
        fast.canonicalize("  " + _tile_sql("ASIA", 25, 1994).lower() + "  ")
        s = fast.template_stats()
        assert s["templates"] == 1 and s["template_hits"] == 1

    @settings(max_examples=60, deadline=None)
    @given(
        region=st.sampled_from(["ASIA", "EUROPE", "AMERICA", "AFRICA"]),
        qty=st.one_of(st.integers(0, 60),
                      st.floats(0.5, 60, allow_nan=False, allow_infinity=False)),
        year=st.integers(1992, 1998),
        upper=st.booleans(),
    )
    def test_property_rebound_equals_cold(self, ssb_small, region, qty, year, upper):
        """Template-rebound signatures are bit-identical to cold parses over
        randomized literals and keyword-case renders.  The fast canonicalizer
        persists across examples, so most draws hit a warm template."""
        sql = _tile_sql(region, qty, year, upper=upper)
        fast = self._shared_fast(ssb_small)
        cold = SQLCanonicalizer(ssb_small.schema, template_cache=False)
        a, c = fast.canonicalize(sql), cold.canonicalize(sql)
        assert a.canonical_json() == c.canonical_json()
        assert a.key() == c.key()
        # the slotted parse itself must reproduce the cold AST exactly
        fp, tokens, values = sp.template_of(sql)
        assert sp.bind_slots(sp.parse_slotted(tokens, sql), values) == sp.parse(sql)

    _FAST = {}

    def _shared_fast(self, wl) -> SQLCanonicalizer:
        return self._FAST.setdefault(wl.name, SQLCanonicalizer(wl.schema))


# ------------------------------------------------------- interned signatures


def _sig(**kw):
    base = dict(schema="ssb", measures=(Measure("SUM", "lineorder.lo_revenue"),))
    base.update(kw)
    return Signature(**base)


class TestInterning:
    def test_key_computed_once_per_instance(self):
        s = _sig(filters=(Filter("customer.c_region", "=", "ASIA"),))
        sigmod.reset_key_hash_computations()
        k1 = s.key()
        assert sigmod.key_hash_computations() == 1
        assert s.key() == k1 and s.canonical_json() == s.canonical_json()
        assert s.measure_key() is s.measure_key()
        assert s.filter_set() is s.filter_set()
        assert sigmod.key_hash_computations() == 1

    def test_equal_sigs_same_key_different_instances(self):
        a = _sig(levels=("customer.c_region",))
        b = _sig(levels=("customer.c_region",))
        assert a is not b and a.key() == b.key()

    def test_filters_frozen_matches_filter_tuple(self):
        f1 = Filter("customer.c_region", "=", "ASIA")
        f2 = Filter("lineorder.lo_quantity", "<", 25)
        s = _sig(filters=(f2, f1))
        assert s.filters_frozen() == frozenset({f1, f2})

    def test_one_hash_per_request_through_service(self, ssb_small):
        """The regression the satellite task asks for: a full request —
        canonicalize, lookup, miss dedup, execute, store — hashes once; a
        memoized repeat (template binding hit -> interned instance) hashes
        zero times."""
        from repro.olap.executor import OlapExecutor
        from repro.service import CacheService, QueryRequest

        svc = CacheService()
        svc.register_tenant("t", schema=ssb_small.schema,
                            backend=OlapExecutor(ssb_small.dataset, impl="numpy"))
        sql = _tile_sql("ASIA", 25, 1994)
        sigmod.reset_key_hash_computations()
        r1 = svc.submit(QueryRequest(sql=sql, tenant="t"))
        assert r1.status == "miss"
        assert sigmod.key_hash_computations() == 1
        sigmod.reset_key_hash_computations()
        r2 = svc.submit(QueryRequest(sql=sql, tenant="t"))
        assert r2.status == "hit_exact"
        assert sigmod.key_hash_computations() == 0

    def test_nl_memo_interaction(self, ssb_small):
        """NL memoization composes with interning: a repeat NL request reuses
        the memoized NLResult's interned signature (zero hashes) and still
        cross-serves the SQL-seeded entry."""
        from repro.core.nl_canon import MemoizedNL, SimulatedLLM
        from repro.olap.executor import OlapExecutor
        from repro.service import CacheService, QueryRequest

        svc = CacheService()
        svc.register_tenant(
            "t", schema=ssb_small.schema,
            backend=OlapExecutor(ssb_small.dataset, impl="numpy"),
            nl=MemoizedNL(SimulatedLLM(ssb_small.vocab, model="oracle")))
        text = "total revenue by customer region in 1994"
        r1 = svc.submit(QueryRequest(nl=text, tenant="t"))
        assert r1.status in ("miss", "bypass")
        sigmod.reset_key_hash_computations()
        r2 = svc.submit(QueryRequest(nl=text, tenant="t"))
        assert sigmod.key_hash_computations() == 0
        if r1.status == "miss":
            assert r2.status.startswith("hit")


# --------------------------------------------------- indexed derivation probes


def _mk_table(levels, n_groups=3, n_measures=1):
    cols = {}
    for i, lv in enumerate(levels):
        cols[lv] = np.asarray([f"v{i}_{g}" for g in range(n_groups)])
    for m in range(n_measures):
        cols[f"m{m}"] = np.arange(n_groups, dtype=np.float64) + m
    return ResultTable(cols)


def _populate(cache, n=1100):
    """>= 1k entries sharing one measure multiset: distinct filter values on
    a shared (city, nation) grouping, plus a few level/window variants."""
    tw = TimeWindow("1994-01-01", "1995-01-01")
    levels = ("customer.c_city", "customer.c_nation")
    for i in range(n):
        sig = _sig(levels=levels,
                   filters=(Filter("lineorder.lo_quantity", "<", i),),
                   time_window=tw)
        cache.put(sig, _mk_table(levels))
    # one coarse entry under a different window (must never serve tw probes)
    other = _sig(levels=("customer.c_nation",),
                 filters=(Filter("lineorder.lo_quantity", "<", 7),),
                 time_window=TimeWindow("1996-01-01", "1997-01-01"))
    cache.put(other, _mk_table(("customer.c_nation",)))
    return tw, levels


@pytest.fixture(scope="module")
def big_caches(ssb_small):
    indexed = SemanticCache(ssb_small.schema, enable_compose=True)
    linear = SemanticCache(ssb_small.schema, enable_compose=True,
                           indexed_probes=False)
    tw, levels = _populate(indexed)
    _populate(linear)
    return indexed, linear, tw, levels


class TestIndexedDerivations:
    def _probes(self, tw, levels):
        return [
            # roll-up: filters match exactly one entry, coarser level
            _sig(levels=("customer.c_nation",),
                 filters=(Filter("lineorder.lo_quantity", "<", 500),),
                 time_window=tw),
            # filter-down: same levels, one extra filter on a grouping column
            _sig(levels=levels,
                 filters=(Filter("lineorder.lo_quantity", "<", 501),
                          Filter("customer.c_nation", "=", "v1_0")),
                 time_window=tw),
            # compose: coarser level + extra filter on a cached grouping col
            _sig(levels=("customer.c_nation",),
                 filters=(Filter("lineorder.lo_quantity", "<", 502),
                          Filter("customer.c_city", "=", "v0_1")),
                 time_window=tw),
            # miss: unknown filter set, different window
            _sig(levels=levels,
                 filters=(Filter("lineorder.lo_quantity", "<", 99999),),
                 time_window=TimeWindow("1990-01-01", "1991-01-01")),
            # miss: post-aggregated request can never derive
            _sig(levels=("customer.c_nation",),
                 filters=(Filter("lineorder.lo_quantity", "<", 500),),
                 time_window=tw, order_by=(sigmod.OrderKey("measure:0", True),),
                 limit=3),
        ]

    def test_outcomes_match_linear_scan(self, big_caches):
        indexed, linear, tw, levels = big_caches
        for sig in self._probes(tw, levels):
            a = indexed.lookup(sig)
            b = linear.lookup(sig)
            assert a.status == b.status, sig.canonical_json()
            assert a.source_key == b.source_key
            if a.table is not None:
                assert a.table.equals(b.table)
        assert indexed.stats.hits_rollup >= 1
        assert indexed.stats.hits_filterdown >= 1
        assert indexed.stats.hits_compose >= 1

    def test_bounded_candidate_subset(self, big_caches):
        """With >= 1k entries in the measure bucket, the indexed probe plans
        over only the structurally viable few; the linear scan walks the
        bucket."""
        indexed, linear, tw, levels = big_caches
        probe = _sig(levels=("customer.c_nation",),
                     filters=(Filter("lineorder.lo_quantity", "<", 600),),
                     time_window=tw)
        for c in (indexed, linear):
            c.stats.derivation_candidates_scanned = 0
            c.stats.derivation_plans_attempted = 0
            assert c.lookup(probe).status == "hit_rollup"
        assert indexed.stats.derivation_candidates_scanned <= 4
        assert indexed.stats.derivation_plans_attempted <= 4
        assert linear.stats.derivation_candidates_scanned >= 500

    def test_eviction_unindexes_tier2(self, ssb_small):
        cache = SemanticCache(ssb_small.schema, capacity=4)
        tw = TimeWindow("1994-01-01", "1995-01-01")
        levels = ("customer.c_city", "customer.c_nation")
        for i in range(8):
            cache.put(_sig(levels=levels,
                           filters=(Filter("lineorder.lo_quantity", "<", i),),
                           time_window=tw), _mk_table(levels))
        assert len(cache) == 4
        # the evicted entries' filter tuples are gone from every index tier
        bucket = next(iter(cache._by_measures.values()))
        assert len(bucket.order) == 4
        twb = bucket.by_tw[tw]
        assert sum(len(v) for v in twb.by_filters.values()) == 4
        assert sum(len(v) for v in twb.by_levels.values()) == 4
        # probes still work against the survivors
        probe = _sig(levels=("customer.c_nation",),
                     filters=(Filter("lineorder.lo_quantity", "<", 6),),
                     time_window=tw)
        assert cache.lookup(probe).status == "hit_rollup"


# ------------------------------------------------------------- observability


def test_service_stats_expose_frontend(ssb_small):
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService, QueryRequest

    svc = CacheService()
    svc.register_tenant("t", schema=ssb_small.schema,
                        backend=OlapExecutor(ssb_small.dataset, impl="numpy"))
    sql = _tile_sql("ASIA", 25, 1994)
    for _ in range(3):
        svc.submit(QueryRequest(sql=sql, tenant="t"))
    svc.submit(QueryRequest(sql="  " + sql.lower(), tenant="t"))  # re-format
    st_ = svc.stats("t")
    tc = st_["frontend"]["template_cache"]
    assert tc["template_misses"] == 1 and tc["text_hits"] == 2
    assert tc["template_hits"] == 1  # the re-formatted text reused the template
    stages = st_["service"]["stages_ms"]
    assert {"canonicalize", "lookup"} <= set(stages)
    assert stages["lookup"]["n"] == 4 and stages["lookup"]["p50_ms"] >= 0.0
    cache_stats = st_["cache"]
    assert "derivation_candidates_scanned" in cache_stats
    assert "derivation_plans_attempted" in cache_stats
    import json
    json.dumps(st_)  # the whole stats payload must stay JSON-serializable
