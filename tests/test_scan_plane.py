"""Partition-parallel scan plane (ISSUE 6).

Tentpole: row-range partitioned fused scans with merge-combine
(``scan_plane`` planning/decomposition + ``refresh.merge_partials``) and
streaming chunked execution for beyond-device-memory datasets, exposed as
``OlapExecutor(partitions=N, max_device_rows=...)``.  The governing property
everywhere: the merged partial tables must equal the unpartitioned fused
scan (``partitions=1`` is the differential oracle), and ``rows_scanned``
must account each fact row exactly once per scan — no double count at chunk
boundaries.

Satellites covered here: the generalized k-way merge combiner's edge cases
(empty partials, all-NaN MIN/MAX, single-partition groups, fold-order
invariance as a Hypothesis property), memo-dict LRU bounds, non-composable
fallback routing, and service-pipeline integration.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import Measure, SemanticCache, Signature
from repro.core.refresh import merge_partials, merge_tables
from repro.core.sql_canon import SQLCanonicalizer
from repro.core.table import ResultTable
from repro.olap import scan_plane
from repro.olap.executor import OlapExecutor
from repro.service.api import QueryRequest
from repro.service.service import CacheService
from repro.workloads import ssb

pytestmark = pytest.mark.filterwarnings("error::RuntimeWarning")


SIG = lambda *ms, **kw: Signature("ssb", tuple(ms), **kw)  # noqa: E731


# -------------------------------------------------------------- plan_scan


class TestPlanScan:
    def test_partitions_cover_rows_disjointly(self):
        for n, p in [(10, 1), (10, 3), (4000, 4), (7, 16), (1, 1)]:
            plan = scan_plane.plan_scan(n, p)
            ranges = [r for part in plan.chunks for r in part]
            assert ranges[0][0] == 0 and ranges[-1][1] == n
            for (_, e1), (s2, _) in zip(ranges, ranges[1:]):
                assert e1 == s2  # adjacent: no gap, no overlap
            assert sum(e - s for s, e in ranges) == n
            assert plan.num_partitions <= p

    def test_more_partitions_than_rows_drops_empties(self):
        plan = scan_plane.plan_scan(3, 8)
        assert plan.num_partitions == 3
        assert all(len(c) == 1 for c in plan.chunks)

    def test_streaming_chunks_are_pow2_sized(self):
        plan = scan_plane.plan_scan(10_000, 2, max_device_rows=1000)
        assert plan.streaming
        for part in plan.chunks:
            # every chunk but the partition's last is the same pow2 size
            sizes = [e - s for s, e in part]
            assert all(sz == 512 for sz in sizes[:-1])
            assert sizes[-1] <= 512
        assert sum(e - s for part in plan.chunks for s, e in part) == 10_000

    def test_no_streaming_when_partition_fits(self):
        plan = scan_plane.plan_scan(1000, 4, max_device_rows=250)
        assert not plan.streaming

    def test_rejects_bad_args(self):
        with pytest.raises(ValueError):
            scan_plane.plan_scan(10, 0)
        with pytest.raises(ValueError):
            scan_plane.plan_scan(10, 2, max_device_rows=0)


# ------------------------------------------------------------- decompose


class TestDecompose:
    def test_avg_becomes_sum_count(self):
        sig = SIG(Measure("AVG", "lineorder.lo_revenue"), levels=("customer.c_region",))
        plan = scan_plane.decompose(sig)
        aggs = [(m.agg, m.expr) for m in plan.partial_sig.measures]
        assert aggs == [("SUM", "lineorder.lo_revenue"), ("COUNT", "*")]
        assert plan.finalize == (("avg", 0, 1),)

    def test_dedup_shares_partial_columns(self):
        sig = SIG(Measure("SUM", "lineorder.lo_revenue"),
                  Measure("AVG", "lineorder.lo_revenue"),
                  Measure("COUNT", "*"))
        plan = scan_plane.decompose(sig)
        # SUM and COUNT(*) partials are shared with the AVG decomposition
        assert len(plan.partial_sig.measures) == 2
        assert plan.finalize == (("direct", 0), ("avg", 0, 1), ("direct", 1))

    def test_post_aggregation_stripped_from_partials(self):
        from repro.core.signature import HavingClause, OrderKey

        sig = SIG(Measure("SUM", "lineorder.lo_revenue"),
                  levels=("customer.c_region",),
                  having=(HavingClause(0, ">", 0),),
                  order_by=(OrderKey("measure:0", desc=True),), limit=3)
        p = scan_plane.decompose(sig)
        assert not p.partial_sig.having and not p.partial_sig.order_by
        assert p.partial_sig.limit is None

    def test_count_distinct_not_partitionable(self):
        sig = SIG(Measure("COUNT", "lineorder.lo_custkey", distinct=True))
        assert not scan_plane.partition_compatible(sig)
        with pytest.raises(ValueError):
            scan_plane.decompose(sig)


# ---------------------------------------------------- k-way merge combiner


def _grouped_sig(*aggs):
    return SIG(*[Measure(a, "lineorder.lo_revenue") if a != "COUNT"
                 else Measure("COUNT", "*") for a in aggs],
               levels=("customer.c_region",))


def _tbl(keys, **measures):
    cols = {} if keys is None else {"customer.c_region": np.asarray(keys)}
    for name, vals in measures.items():
        cols[name] = np.asarray(vals, np.float64)
    return ResultTable(cols)


class TestMergePartials:
    def test_two_way_matches_merge_tables(self):
        sig = _grouped_sig("SUM", "COUNT")
        a = _tbl(["E", "W"], m0=[10.0, 20.0], m1=[1, 2])
        b = _tbl(["W", "N"], m0=[5.0, 7.0], m1=[1, 1])
        assert merge_partials(sig, [a, b]).equals(merge_tables(sig, a, b),
                                                  ordered=True)

    def test_empty_partitions_are_transparent(self):
        sig = _grouped_sig("SUM")
        empty = _tbl([], m0=[])
        a = _tbl(["E"], m0=[3.0])
        m = merge_partials(sig, [empty, a, empty, empty])
        assert m.equals(a, ordered=True)
        # all partitions empty: an empty table with the right columns
        assert merge_partials(sig, [empty, empty]).num_rows == 0

    def test_all_nan_minmax_partials_poison_group(self):
        sig = _grouped_sig("MIN", "MAX")
        a = _tbl(["E"], m0=[np.nan], m1=[np.nan])
        b = _tbl(["E"], m0=[np.nan], m1=[np.nan])
        c = _tbl(["E", "W"], m0=[1.0, 2.0], m1=[5.0, 6.0])
        m = merge_partials(sig, [a, b, c])
        assert np.isnan(m.columns["m0"][0]) and np.isnan(m.columns["m1"][0])
        assert m.columns["m0"][1] == 2.0 and m.columns["m1"][1] == 6.0

    def test_groups_in_only_one_partition_survive(self):
        sig = _grouped_sig("SUM", "MIN")
        a = _tbl(["E"], m0=[1.0], m1=[10.0])
        b = _tbl(["N"], m0=[2.0], m1=[20.0])
        c = _tbl(["W"], m0=[3.0], m1=[30.0])
        m = merge_partials(sig, [a, b, c])
        assert m.columns["customer.c_region"].tolist() == ["E", "N", "W"]
        assert m.columns["m0"].tolist() == [1.0, 2.0, 3.0]
        assert m.columns["m1"].tolist() == [10.0, 20.0, 30.0]

    def test_global_aggregate_folds_all_partials(self):
        sig = SIG(Measure("SUM", "lineorder.lo_revenue"),
                  Measure("MIN", "lineorder.lo_revenue"))
        parts = [_tbl(None, m0=[float(i)], m1=[float(10 - i)])
                 for i in range(5)]
        m = merge_partials(sig, parts)
        assert float(m.columns["m0"][0]) == 10.0  # 0+1+2+3+4
        assert float(m.columns["m1"][0]) == 6.0

    def test_rejects_non_mergeable_and_empty_input(self):
        sig = SIG(Measure("AVG", "lineorder.lo_revenue"))
        with pytest.raises(ValueError):
            merge_partials(sig, [_tbl(None, m0=[1.0])])
        with pytest.raises(ValueError):
            merge_partials(_grouped_sig("SUM"), [])

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n_parts=st.integers(2, 6),
        perm_seed=st.integers(0, 10_000),
    )
    def test_fold_order_never_changes_merge(self, seed, n_parts, perm_seed):
        """Permuting the partial tables must give the identical merged table
        (integer-valued measures + NaN, so equality is exact: SUM regrouping
        of integers inside f64 has no rounding)."""
        rng = np.random.default_rng(seed)
        sig = _grouped_sig("SUM", "COUNT", "MIN", "MAX")
        keys = np.asarray(["A", "B", "C", "D", "E"])
        parts = []
        for _ in range(n_parts):
            k = rng.integers(0, 5, size=rng.integers(0, 5))
            vals = rng.integers(-50, 50, size=len(k)).astype(np.float64)
            vals[rng.random(len(k)) < 0.2] = np.nan  # NaN partials included
            parts.append(_tbl(keys[k],
                              m0=np.where(np.isnan(vals), 0.0, vals),
                              m1=np.ones(len(k)), m2=vals, m3=vals))
        merged = merge_partials(sig, parts)
        perm = np.random.default_rng(perm_seed).permutation(n_parts)
        remerged = merge_partials(sig, [parts[i] for i in perm])
        assert merged.columns.keys() == remerged.columns.keys()
        for name in merged.columns:
            a, b = merged.columns[name], remerged.columns[name]
            if a.dtype.kind == "f":
                np.testing.assert_array_equal(a, b)  # exact, NaN == NaN
            else:
                assert a.tolist() == b.tolist()


# --------------------------------------------- partitioned executor oracle


class TestPartitionedExecutor:
    def test_all_intents_match_unpartitioned_oracle(self, ssb_small,
                                                    tlc_small, tpcds_small):
        """Merged partial tables == the unpartitioned fused scan for every
        canonical intent of every workload (the tentpole's zero-drift
        guarantee)."""
        for wl in (ssb_small, tlc_small, tpcds_small):
            canon = SQLCanonicalizer(wl.schema)
            ex1 = OlapExecutor(wl.dataset, impl="xla")
            ex4 = OlapExecutor(wl.dataset, impl="xla", partitions=4)
            for intent in wl.intents:
                sig = canon.canonicalize(intent.sql)
                a = ex1.execute(sig)
                b = ex4.execute(sig)
                assert a.equals(b, ordered=bool(sig.order_by)), intent.id

    def test_streaming_matches_oracle_and_counts_chunks(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        ex1 = OlapExecutor(ssb_small.dataset, impl="xla")
        exs = OlapExecutor(ssb_small.dataset, impl="xla", partitions=2,
                           max_device_rows=700)  # 2000-row partitions stream
        for intent in ssb_small.intents[:6]:
            sig = canon.canonicalize(intent.sql)
            assert ex1.execute(sig).equals(exs.execute(sig),
                                           ordered=bool(sig.order_by)), intent.id
        st = exs.stats()
        assert st["streaming_chunks"] > 0
        assert all(p["chunks"] > 0 for p in st["per_partition"])

    def test_rows_scanned_matches_unpartitioned(self, ssb_small):
        """Partition-edge accounting: the partitioned scan must count each
        fact row exactly once per scan — summed across partitions and chunks
        it equals the unpartitioned count (no boundary double-count)."""
        canon = SQLCanonicalizer(ssb_small.schema)
        sigs = [canon.canonicalize(i.sql) for i in ssb_small.intents[:5]]
        ex1 = OlapExecutor(ssb_small.dataset, impl="xla")
        ex4 = OlapExecutor(ssb_small.dataset, impl="xla", partitions=4)
        exs = OlapExecutor(ssb_small.dataset, impl="xla", partitions=3,
                           max_device_rows=500)
        for sig in sigs:
            ex1.execute(sig)
            ex4.execute(sig)
            exs.execute(sig)
        assert ex4.rows_scanned == ex1.rows_scanned
        assert exs.rows_scanned == ex1.rows_scanned
        per_part = ex4.stats()["per_partition"]
        assert sum(p["rows_scanned"] for p in per_part) == ex1.rows_scanned
        sizes = [p["end"] - p["start"] for p in per_part]
        for p, sz in zip(per_part, sizes):
            assert p["rows_scanned"] == sz * len(sigs)

    def test_batch_matches_unpartitioned_batch(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        sigs = [canon.canonicalize(i.sql) for i in ssb_small.intents]
        ex1 = OlapExecutor(ssb_small.dataset, impl="xla")
        ex4 = OlapExecutor(ssb_small.dataset, impl="xla", partitions=4)
        for a, b, s in zip(ex1.execute_batch(sigs), ex4.execute_batch(sigs),
                           sigs):
            assert a.equals(b, ordered=bool(s.order_by))
        assert ex4.rows_scanned == ex1.rows_scanned

    def test_count_distinct_falls_back_to_single_partition(self, ssb_small):
        sig = SIG(Measure("COUNT", "lineorder.lo_custkey", distinct=True),
                  levels=("customer.c_region",))
        ex1 = OlapExecutor(ssb_small.dataset, impl="xla")
        ex4 = OlapExecutor(ssb_small.dataset, impl="xla", partitions=4)
        assert ex1.execute(sig).equals(ex4.execute(sig))
        st = ex4.stats()
        assert st["partition_fallbacks"] == 1
        assert st["partitioned_scans"] == 0

    def test_numpy_impl_partitions_through_host_oracle(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        ex1 = OlapExecutor(ssb_small.dataset, impl="numpy")
        ex3 = OlapExecutor(ssb_small.dataset, impl="numpy", partitions=3)
        for intent in ssb_small.intents[:6]:
            sig = canon.canonicalize(intent.sql)
            assert ex1.execute(sig).equals(ex3.execute(sig),
                                           ordered=bool(sig.order_by)), intent.id

    def test_append_resyncs_partition_layout(self):
        """A delta append bumps the dataset version: the scan plan, resident
        subs, and per-partition stats must rebuild over the grown table."""
        from benchmarks.bench_refresh import make_delta

        wl = ssb.build(n_fact=3000, seed=0)
        canon = SQLCanonicalizer(wl.schema)
        sig = canon.canonicalize(
            "SELECT c_region, SUM(lo_revenue) AS r FROM lineorder "
            "JOIN customer ON lineorder.lo_custkey = customer.c_key "
            "GROUP BY c_region")
        ex1 = OlapExecutor(wl.dataset, impl="xla")
        ex4 = OlapExecutor(wl.dataset, impl="xla", partitions=4)
        assert ex1.execute(sig).equals(ex4.execute(sig))
        wl.dataset.append_rows(make_delta(wl.dataset, 500,
                                          np.random.default_rng(7)))
        a, b = ex1.execute(sig), ex4.execute(sig)
        assert a.equals(b)
        parts = ex4.stats()["per_partition"]
        assert parts[-1]["end"] == wl.dataset.fact.num_rows


# --------------------------------------------------------- memo LRU bounds


class TestMemoBounds:
    def test_memos_never_exceed_cap(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        ex = OlapExecutor(ssb_small.dataset, impl="xla", memo_cap=2)
        for intent in ssb_small.intents:
            ex.execute(canon.canonicalize(intent.sql))
        sizes = ex.memo_sizes()
        for name in ("level_plans", "gids", "rect_index", "measure_plans"):
            assert sizes[name] <= 2, (name, sizes)

    def test_eviction_releases_device_arrays_and_stays_correct(self):
        # fresh workload: the session fixture's device mirror is shared by
        # other tests' executors, so its store counts aren't attributable
        wl = ssb.build(n_fact=2000, seed=5)
        canon = SQLCanonicalizer(wl.schema)
        sigs = [canon.canonicalize(i.sql) for i in wl.intents]
        oracle = OlapExecutor(wl.dataset, impl="numpy")
        ex = OlapExecutor(wl.dataset, impl="xla", memo_cap=1)
        # two passes: the second re-executes signatures whose plans were
        # evicted, exercising rebuild-after-eviction
        for _ in range(2):
            for s in sigs:
                assert oracle.execute(s).equals(ex.execute(s),
                                                ordered=bool(s.order_by))
        store = ex.ds._device._store
        # the ('gids', ()) global-aggregate entry is built inline (never in
        # the LRU) and is bounded at one; every level-combination entry must
        # have been evicted down to the cap
        n_gids = sum(1 for k in store if k[0] == "gids" and k[1] != ())
        n_rect = sum(1 for k in store if k[0] == "rectidx")
        n_sum = sum(1 for k in store if k[0] == "sumblock")
        assert n_gids <= 1 and n_rect <= 1 and n_sum <= 1, set(store)

    def test_stats_exposes_memo_sizes(self, ssb_small):
        ex = OlapExecutor(ssb_small.dataset, impl="xla")
        assert "memo_sizes" in ex.stats()
        assert set(ex.memo_sizes()) >= {"level_plans", "gids", "rect_index",
                                        "measure_plans"}


# --------------------------------------------------------- service plumbing


class TestServiceIntegration:
    def _mk(self, wl, partitions, shards=None):
        be = OlapExecutor(wl.dataset, impl="xla", partitions=partitions)
        svc = CacheService()
        svc.register_tenant(
            "t", schema=wl.schema, backend=be,
            cache=SemanticCache(wl.schema,
                                level_mapper=wl.dataset.level_mapper()),
            shards=shards)
        return svc, be

    def test_miss_group_executes_partitioned(self, ssb_small):
        svc1, _ = self._mk(ssb_small, 1)
        svc4, be4 = self._mk(ssb_small, 4)
        reqs = [QueryRequest(sql=i.sql, tenant="t")
                for i in ssb_small.intents[:6]]
        r1 = svc1.submit_batch(reqs)
        r4 = svc4.submit_batch(reqs)
        for a, b in zip(r1, r4):
            assert a.status == b.status == "miss"
            assert a.table.equals(b.table, ordered=False)
            assert "execute:partitioned" in b.provenance
            assert "execute:partitioned" not in a.provenance
        # one shared partitioned scan served the whole miss group
        assert be4.partitioned_scans == 1
        st = svc4.stats("t")
        assert st["backend"]["partitions"] == 4
        assert len(st["backend"]["per_partition"]) == 4

    def test_cluster_leaders_share_one_partitioned_scan(self, ssb_small):
        """With a partition-parallel backend the cluster pipeline must NOT
        nest its shard pool on top of the partition pool: all miss leaders
        go through one cross-family execute_batch."""
        svc, be = self._mk(ssb_small, 4, shards=4)
        reqs = [QueryRequest(sql=i.sql, tenant="t")
                for i in ssb_small.intents[:6]]
        results = svc.submit_batch(reqs)
        assert all(r.status == "miss" for r in results)
        assert be.partitioned_scans == 1  # not one per shard group
        assert be.batch_calls == 1
        # warm pass: everything hits, no further scans
        again = svc.submit_batch(reqs)
        assert all(r.status.startswith("hit") for r in again)
        assert be.partitioned_scans == 1

    def test_advance_snapshot_keeps_delta_scan_single_partition(self):
        """The refresh delta scan stays partition-bounded (cost proportional
        to the delta): ``execute_batch(partition=...)`` must not route
        through the scan plane even on a partitioned backend."""
        from benchmarks.bench_refresh import make_delta

        wl = ssb.build(n_fact=3000, seed=0)
        svc, be = self._mk(wl, 4)
        sql = ("SELECT c_region, SUM(lo_revenue) AS r, COUNT(*) AS n "
               "FROM lineorder "
               "JOIN customer ON lineorder.lo_custkey = customer.c_key "
               "GROUP BY c_region")
        first = svc.submit(QueryRequest(sql=sql, tenant="t"))
        assert first.status == "miss"
        scans_before = be.partitioned_scans
        delta = make_delta(wl.dataset, 400, np.random.default_rng(11))
        svc.advance_snapshot("t", delta=delta, snapshot_id="snap1")
        assert be.partitioned_scans == scans_before  # delta scan, not plane
        refreshed = svc.submit(QueryRequest(sql=sql, tenant="t"))
        assert refreshed.status.startswith("hit")
        oracle = OlapExecutor(wl.dataset, impl="numpy")
        canon = SQLCanonicalizer(wl.schema)
        assert refreshed.table.equals(oracle.execute(canon.canonicalize(sql)))
