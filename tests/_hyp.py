"""Optional-hypothesis shim (see requirements-dev.txt for the pinned dep).

Property-based tests import ``given``/``settings``/``st`` from here instead of
hard-importing ``hypothesis``: when the package is absent the decorators
degrade to ``pytest.mark.skip`` so the property tests skip individually while
the rest of the module still collects and runs (a bare import error would
knock out the whole test session; ``pytest.importorskip`` at module level
would skip every non-property test in the module too).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without dev deps
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None, so module-level strategy definitions still
        evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn
