"""SQL parsing + canonicalization: subset coverage, bypass triggers,
variant unification (the paper's core SQL-side claim)."""
import pytest

from repro.core.sql_canon import CanonicalizationError, SQLCanonicalizer
from repro.core.sqlparse import SQLSyntaxError, UnsupportedQuery, parse
from repro.workloads.variants import make_variants


UNSUPPORTED = [
    "SELECT a FROM t UNION SELECT b FROM u",
    "WITH x AS (SELECT 1) SELECT * FROM x",
    "SELECT SUM(x) OVER (PARTITION BY y) FROM t",
    "SELECT DISTINCT a FROM t",
    "SELECT a FROM t LEFT JOIN u ON t.x = u.y",
    "SELECT a FROM t WHERE x = 1 OR y = 2",
    "SELECT a FROM t WHERE x IN (SELECT y FROM u)",
    "SELECT a FROM t WHERE name LIKE 'x%'",
    "SELECT MEDIAN(x) FROM t",
]


@pytest.mark.parametrize("sql", UNSUPPORTED)
def test_unsupported_constructs_bypass(sql):
    with pytest.raises(UnsupportedQuery):
        parse(sql)


def test_syntax_errors():
    for sql in ["SELECT", "SELECT FROM t", "SELECT a FROM", "FROM t SELECT a"]:
        with pytest.raises((SQLSyntaxError, UnsupportedQuery)):
            parse(sql)


def test_comments_and_literals():
    q = parse("SELECT SUM(x) -- trailing\nFROM t /* block */ WHERE s = 'o''brien'")
    assert q.where[0].right.value == "o'brien"


class TestCanonicalization:
    def test_variant_unification_all_workloads(self, ssb_small, tlc_small, tpcds_small):
        """21 systematic variants -> one signature, for every intent."""
        for wl in (ssb_small, tlc_small, tpcds_small):
            canon = SQLCanonicalizer(wl.schema)
            for i, intent in enumerate(wl.intents):
                variants = make_variants(intent.sql, wl.schema, n=21, seed=i)
                keys = {canon.canonicalize(v).key() for v in variants}
                assert len(keys) == 1, f"{intent.id} fragmented: {len(keys)} keys"

    def test_distinct_intents_distinct_keys(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        keys = [canon.canonicalize(i.sql).key() for i in ssb_small.intents]
        assert len(set(keys)) == len(keys)

    def test_time_folding_equivalence(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        a = canon.canonicalize(
            "SELECT SUM(lo_revenue) r FROM lineorder "
            "JOIN dates ON lineorder.lo_orderdate = dates.d_key WHERE d_year = 1994")
        b = canon.canonicalize(
            "SELECT SUM(lo_revenue) r FROM lineorder "
            "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
            "WHERE lo_date >= '1994-01-01' AND lo_date < '1995-01-01'")
        assert a.key() == b.key()
        assert a.time_window.start == "1994-01-01"

    def test_unknown_column_rejected(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(CanonicalizationError):
            canon.canonicalize("SELECT SUM(nonexistent) FROM lineorder")

    def test_unjoined_dimension_rejected(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(CanonicalizationError):
            canon.canonicalize(
                "SELECT c_region, SUM(lo_revenue) r FROM lineorder GROUP BY c_region")

    def test_wrong_join_path_rejected(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(CanonicalizationError):
            canon.canonicalize(
                "SELECT SUM(lo_revenue) r FROM lineorder "
                "JOIN customer ON lineorder.lo_suppkey = customer.c_key")

    def test_role_playing_double_join_bypasses(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(UnsupportedQuery):
            canon.canonicalize(
                "SELECT SUM(lo_revenue) r FROM lineorder "
                "JOIN customer c1 ON lineorder.lo_custkey = c1.c_key "
                "JOIN customer c2 ON lineorder.lo_custkey = c2.c_key")

    def test_select_not_in_group_by_rejected(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(CanonicalizationError):
            canon.canonicalize(
                "SELECT c_region, c_nation, SUM(lo_revenue) r FROM lineorder "
                "JOIN customer ON lineorder.lo_custkey = customer.c_key "
                "GROUP BY c_region")

    def test_limit_without_order_bypasses(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(UnsupportedQuery):
            canon.canonicalize(
                "SELECT c_region, SUM(lo_revenue) r FROM lineorder "
                "JOIN customer ON lineorder.lo_custkey = customer.c_key "
                "GROUP BY c_region LIMIT 5")

    def test_agg_on_string_rejected(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        with pytest.raises(CanonicalizationError):
            canon.canonicalize(
                "SELECT SUM(c_region) FROM lineorder "
                "JOIN customer ON lineorder.lo_custkey = customer.c_key")

    def test_commutative_expr_unified(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema)
        a = canon.canonicalize(
            "SELECT SUM(lo_extendedprice * lo_discount) x FROM lineorder")
        b = canon.canonicalize(
            "SELECT SUM(lo_discount * lo_extendedprice) x FROM lineorder")
        assert a.key() == b.key()
