"""Concurrency invariant analysis plane: golden-finding tests over
known-bad fixture modules, live-tree cleanliness, the extracted lock-order
graph, the runtime sanitizer (including a deliberate lock inversion), and
thread-safety regression storms for the canonicalizer fast paths the
lock-discipline pass surfaced."""
import os
import threading
import time
from collections import Counter as TallyCounter

import pytest

from repro.analysis import annotations as anns
from repro.analysis import immutability, lockcheck, lockorder, sanitizer
from repro.analysis.cli import _default_paths, _repo_root, main as cli_main
from repro.analysis.findings import load_baseline, split_baseline
from repro.core import MemoizedNL
from repro.core.sql_canon import SQLCanonicalizer

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "analysis_cases")


def fixture_index():
    return anns.build_index([FIXTURES], repo_root=FIXTURES)


# ----------------------------------------------------- golden fixture runs


class TestGoldenFindings:
    def test_lock_discipline_findings(self):
        findings, waived = lockcheck.run(fixture_index())
        got = TallyCounter(
            (f.rule, f.identifier) for f in findings
            if f.file == "bad_guarded.py")
        assert got == TallyCounter({
            ("guarded-by", "Counter.hits"): 3,   # plain, +=, cross-receiver
            ("guarded-by", "Counter.items"): 2,  # mutator, wrong-lock store
            ("unannotated-shared-write", "Counter.notes"): 1,
        })
        lines = {f.identifier: f.line for f in findings
                 if f.file == "bad_guarded.py"}
        assert all(v > 0 for v in lines.values())
        assert [w.identifier for w in waived
                if w.file == "bad_guarded.py"] == ["Counter.hits"]

    def test_guarded_write_under_lock_is_clean(self):
        findings, _ = lockcheck.run(fixture_index())
        flagged = {f"{f.file}:{f.line}" for f in findings}
        src = open(os.path.join(FIXTURES, "bad_guarded.py")).read()
        # the good_* methods must produce nothing
        for marker in ("with self._lock", "good_acquire_pairing",
                       "good_external"):
            assert marker in src
        bad_lines = {int(line.split(":")[1]) for line in flagged
                     if line.startswith("bad_guarded.py")}
        lines = src.splitlines()
        for ln in bad_lines:
            assert "FINDING" in lines[ln - 1]

    def test_lock_order_cycles(self):
        findings, _, edges = lockorder.run(fixture_index())
        idents = sorted(f.identifier for f in findings)
        assert idents == [
            "cycle:Inverted._a -> Inverted._b -> Inverted._a",
            "cycle:ViaCall._inner -> ViaCall._outer -> ViaCall._inner",
        ]
        # the via-call cycle needs the call-summary fixpoint: nested()'s
        # acquisition of _outer must propagate to take_outer's call site
        assert ("ViaCall._inner", "ViaCall._outer") in edges
        assert "via ViaCall.nested" in edges[("ViaCall._inner",
                                              "ViaCall._outer")]

    def test_immutability_findings(self):
        findings, waived = lockcheck.run(fixture_index())  # no frozen hits
        assert not [f for f in findings if f.rule == "immutability"]
        findings, waived = immutability.run(fixture_index())
        got = sorted((f.rule, f.identifier) for f in findings)
        assert got == [("immutability", "Point.x"),
                       ("immutability", "Point.y")]
        assert [w.identifier for w in waived] == ["Point.y"]


# ------------------------------------------------------- live-tree checks


class TestLiveTree:
    def test_analysis_is_clean_beyond_baseline(self, capsys):
        assert cli_main(["--strict", "--json", "-"]) == 0
        out = capsys.readouterr().out
        assert "clean: no findings beyond baseline" in out

    def test_baseline_is_empty_by_policy(self):
        path = os.path.join(_repo_root(), "src", "repro", "analysis",
                            "baseline.json")
        assert load_baseline(path) == set()

    def test_split_baseline_keys_ignore_lines(self):
        from repro.analysis.findings import Finding
        f = Finding(rule="guarded-by", file="x.py", line=99,
                    identifier="C.a", message="m")
        new, old = split_baseline([f], {("guarded-by", "x.py", "C.a")})
        assert new == [] and old == [f]

    def test_live_lock_graph_shape(self):
        root = _repo_root()
        index = anns.build_index(_default_paths(root), repo_root=root)
        findings, _, edges = lockorder.run(index)
        assert findings == []  # acyclic
        expected = {
            ("CacheCluster._topology_lock", "CacheShard.lock"),
            ("OlapExecutor._scan_mutex", "OlapExecutor._count_lock"),
            ("OlapExecutor._subs_lock", "OlapExecutor._count_lock"),
            ("ReadWriteGate.write", "CacheShard.lock"),
        }
        assert expected <= set(edges)

    def test_guarded_annotations_cover_concurrent_classes(self):
        root = _repo_root()
        index = anns.build_index(_default_paths(root), repo_root=root)
        guarded_by_class = {}
        for mod in index.modules:
            for cinfo in mod.classes.values():
                if cinfo.guarded:
                    guarded_by_class[cinfo.name] = set(cinfo.guarded)
        assert "CacheShard" in guarded_by_class
        assert "_inflight" in guarded_by_class["CacheShard"]
        assert {"table", "error"} <= guarded_by_class["Flight"]
        assert "_templates" in guarded_by_class["SQLCanonicalizer"]
        assert "_memo" in guarded_by_class["MemoizedNL"]
        assert "_tenants" in guarded_by_class["CacheService"]
        assert "snapshot_id" in guarded_by_class["Tenant"]


# ------------------------------------------------------- runtime sanitizer


@pytest.fixture()
def clean_sanitizer():
    sanitizer.reset()
    yield sanitizer
    sanitizer.reset()


class TestSanitizerUnit:
    def test_make_lock_is_plain_when_disabled(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        lk = sanitizer.make_lock("T.lock")
        assert not isinstance(lk, sanitizer.SanitizedLock)
        with lk:
            pass

    def test_make_lock_is_sanitized_when_enabled(self, monkeypatch,
                                                 clean_sanitizer):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        lk = sanitizer.make_lock("T.lock")
        assert isinstance(lk, sanitizer.SanitizedLock)
        with lk:
            assert lk.locked()
        assert not lk.locked()

    def test_deliberate_inversion_is_caught(self, clean_sanitizer):
        a = sanitizer.SanitizedLock("T.a")
        b = sanitizer.SanitizedLock("T.b")
        with a:
            with b:
                pass
        with b:
            with pytest.raises(sanitizer.LockOrderViolation):
                a.acquire()
        assert sanitizer.violations()
        assert "T.b" in sanitizer.observed_edges()["T.a"]

    def test_inversion_caught_across_threads(self, clean_sanitizer):
        a = sanitizer.SanitizedLock("X.a")
        b = sanitizer.SanitizedLock("X.b")

        def fwd():
            with a:
                with b:
                    pass

        t = threading.Thread(target=fwd)
        t.start()
        t.join()

        raised = []

        def bwd():
            with b:
                try:
                    with a:  # demonstrated opposite order: must raise
                        pass
                except sanitizer.LockOrderViolation as e:
                    raised.append(e)

        t2 = threading.Thread(target=bwd)
        t2.start()
        t2.join()
        assert raised
        assert any("lock-order cycle" in v for v in sanitizer.violations())

    def test_reentrant_same_instance_is_fine(self, clean_sanitizer):
        lk = sanitizer.SanitizedLock("T.re", reentrant=True)
        with lk:
            with lk:
                pass
        assert sanitizer.violations() == []

    def test_same_class_nesting_needs_registration(self, clean_sanitizer):
        a1 = sanitizer.SanitizedLock("Shardish.lock")
        a2 = sanitizer.SanitizedLock("Shardish.lock")
        with a1:
            with pytest.raises(sanitizer.LockOrderViolation):
                a2.acquire()
        sanitizer.reset()
        sanitizer.allow_same_class_order("Shardish.lock")
        with a1:
            with a2:
                pass
        assert sanitizer.violations() == []

    def test_note_blocking_flags_held_lock(self, monkeypatch,
                                           clean_sanitizer):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        lk = sanitizer.SanitizedLock("T.lock")
        sanitizer.note_blocking("free")  # nothing held: fine
        with lk:
            with pytest.raises(sanitizer.LockOrderViolation):
                sanitizer.note_blocking("Flight.wait")
        assert any("Flight.wait" in v for v in sanitizer.violations())

    def test_note_blocking_ignores_shared_pseudo(self, monkeypatch,
                                                 clean_sanitizer):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        token = sanitizer.note_acquire("Gate.read", shared=True)
        try:
            sanitizer.note_blocking("Flight.wait")  # shared: no violation
        finally:
            sanitizer.note_release(token)
        assert sanitizer.violations() == []

    def test_pseudo_lock_participates_in_ordering(self, monkeypatch,
                                                  clean_sanitizer):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        lk = sanitizer.SanitizedLock("T.inner")
        token = sanitizer.note_acquire("Gate.write")
        with lk:
            pass
        sanitizer.note_release(token)
        assert "T.inner" in sanitizer.observed_edges()["Gate.write"]
        with lk:
            with pytest.raises(sanitizer.LockOrderViolation):
                sanitizer.note_acquire("Gate.write")


# ------------------------------- canonicalizer thread-safety regressions


class TestCanonicalizerConcurrency:
    """Regressions for the unguarded shared state the lock-discipline pass
    surfaced: the SQL template/text memos + counters, per-parse resolution
    state on the shared canonicalizer, and the NL memo."""

    N_THREADS = 8
    ROUNDS = 24

    def _sqls(self):
        joins = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
                 "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")
        out = []
        for y in (1992, 1993, 1994, 1995):
            out.append(f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder "
                       f"{joins}WHERE d_year = {y} GROUP BY c_region")
        for r in ("'ASIA'", "'AMERICA'"):
            out.append(f"SELECT c_nation, COUNT(*) AS n FROM lineorder "
                       f"{joins}WHERE c_region = {r} GROUP BY c_nation")
        return out

    def test_sql_canonicalizer_storm(self, ssb_small):
        canon = SQLCanonicalizer(ssb_small.schema, max_templates=2,
                                 max_bindings_per_template=4)
        cold = SQLCanonicalizer(ssb_small.schema, template_cache=False)
        sqls = self._sqls()
        expected = {s: cold.canonicalize(s) for s in sqls}
        errors = []
        barrier = threading.Barrier(self.N_THREADS)

        def worker(tid):
            barrier.wait()
            try:
                for i in range(self.ROUNDS):
                    s = sqls[(tid + i) % len(sqls)]
                    assert canon.canonicalize(s) == expected[s]
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        st = canon.template_stats()
        arrivals = self.N_THREADS * self.ROUNDS
        # every arrival resolves through exactly one tier
        assert (st["text_hits"] + st["template_hits"]
                + st["template_misses"]) == arrivals
        assert st["templates"] <= 2
        assert st["bindings"] <= 2 * 4

    def test_from_ast_state_is_parse_scoped(self, ssb_small):
        """Two interleaved from_ast calls with different alias maps must not
        cross-contaminate (the old instance-attribute state did)."""
        canon = SQLCanonicalizer(ssb_small.schema, template_cache=False)
        sqls = self._sqls()
        expected = {s: canon.canonicalize(s) for s in sqls}
        errors = []

        def worker(tid):
            try:
                for i in range(self.ROUNDS):
                    s = sqls[(tid + i) % len(sqls)]
                    assert canon.canonicalize(s) == expected[s]
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []

    def test_memoized_nl_storm(self):
        class CountingInner:
            def __init__(self):
                self.calls = 0
                self._lock = threading.Lock()

            def canonicalize(self, text, now=None):
                with self._lock:
                    self.calls += 1
                time.sleep(0.001)  # widen the race window
                return ("sig", text)

        inner = CountingInner()
        memo = MemoizedNL(inner)
        texts = [f"revenue by region in {y}" for y in range(1992, 1996)]
        errors = []
        barrier = threading.Barrier(self.N_THREADS)

        def worker(tid):
            barrier.wait()
            try:
                for i in range(self.ROUNDS):
                    t = texts[(tid + i) % len(texts)]
                    assert memo.canonicalize(t) == ("sig", t)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        arrivals = self.N_THREADS * self.ROUNDS
        # each arrival is exactly one of: memo hit, inner call
        assert memo.calls + memo.memo_hits == arrivals
        # post-storm, every text is memoized to one canonical result object
        for t in texts:
            assert memo.canonicalize(t) is memo.canonicalize(t)

    def test_memoized_nl_batch_concurrent(self):
        class BatchInner:
            def __init__(self):
                self.batch_calls = 0
                self._lock = threading.Lock()

            def canonicalize(self, text, now=None):
                return ("sig", text)

            def canonicalize_batch(self, texts, now=None):
                with self._lock:
                    self.batch_calls += 1
                time.sleep(0.001)
                return [("sig", t) for t in texts]

        inner = BatchInner()
        memo = MemoizedNL(inner)
        texts = [f"q{i}" for i in range(6)]
        errors = []
        barrier = threading.Barrier(4)

        def worker(tid):
            barrier.wait()
            try:
                for _ in range(10):
                    out = memo.canonicalize_batch(texts)
                    assert out == [("sig", t) for t in texts]
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        assert memo.calls + memo.memo_hits == 4 * 10 * len(texts)
