"""Sharded cache cluster: family-routing invariants, the shards=N vs
unsharded differential oracle, single-flight miss dedup under real threads,
deterministic rebalance migration (entries, LRU order, derivation-index
membership), byte-aware accounting, and TenantStats thread safety."""
import datetime as dt
import json
import threading
import time

import numpy as np
import pytest

from repro.cluster import CacheCluster, CacheShard, family_hash, family_key
from repro.core import MemoizedNL, SemanticCache, SimulatedLLM
from repro.core.sql_canon import SQLCanonicalizer
from repro.core.table import ResultTable
from repro.olap.executor import OlapExecutor
from repro.resilience import ResiliencePolicy, faults
from repro.service import CacheService, QueryRequest

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")


def sql_region(measures, where="", group="c_region"):
    w = f"WHERE {where} " if where else ""
    return (f"SELECT {group.split(',')[0].strip()}, {measures} "
            f"FROM lineorder {JOINS}{w}GROUP BY {group}")


@pytest.fixture()
def canon(ssb_small):
    return SQLCanonicalizer(ssb_small.schema)


@pytest.fixture()
def backend(ssb_small):
    return OlapExecutor(ssb_small.dataset, impl="numpy")


def mk_cluster(wl, shards, **kw):
    return CacheCluster(wl.schema, shards,
                        level_mapper=wl.dataset.level_mapper(), **kw)


def mk_service(wl, shards=None, backend=None, **tenant_kw):
    be = backend or OlapExecutor(wl.dataset, impl="numpy")
    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema, backend=be,
        cache=SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper()),
        shards=shards, **tenant_kw)
    return svc


class CountingBackend:
    """Backend wrapper counting executions, with an optional artificial stall
    to widen race windows (single-flight tests)."""

    def __init__(self, inner, stall_s=0.0, fail_first=False):
        self.inner = inner
        self.stall_s = stall_s
        self.calls = 0
        self._fail_first = fail_first
        self._lock = threading.Lock()

    def execute(self, sig):
        with self._lock:
            self.calls += 1
            fail = self._fail_first
            self._fail_first = False
        if self.stall_s:
            time.sleep(self.stall_s)
        if fail:
            raise RuntimeError("injected backend failure")
        return self.inner.execute(sig)

    def execute_raw(self, sql):
        return self.inner.execute_raw(sql)


# ------------------------------------------------------------------ routing


class TestRouting:
    def test_derivation_family_is_shard_local(self, ssb_small, canon):
        """Roll-up/filter-down candidate pairs share (scope, schema, measure
        multiset), so they must always land on the same shard — the invariant
        that makes per-shard lookups equivalent to a global cache."""
        cluster = mk_cluster(ssb_small, 4)
        for scope in (None, "a", "b"):
            for m in ("SUM(lo_revenue) AS r", "COUNT(*) AS n",
                      "MIN(lo_supplycost) AS lo, SUM(lo_revenue) AS r"):
                fine = canon.canonicalize(
                    sql_region(m, "d_year = 1994", "c_region, c_nation"),
                    scope=scope)
                coarse = canon.canonicalize(
                    sql_region(m, "d_year = 1994"), scope=scope)
                narrowed = canon.canonicalize(
                    sql_region(m, "d_year = 1994 AND c_region = 'ASIA'"),
                    scope=scope)
                assert family_key(fine) == family_key(coarse) == family_key(narrowed)
                idx = cluster.shard_index(fine)
                assert cluster.shard_index(coarse) == idx
                assert cluster.shard_index(narrowed) == idx

    def test_routing_is_deterministic_across_instances(self, ssb_small, canon):
        """Routing hashes only canonical signature content, so a re-parsed
        signature (fresh instance, fresh process semantics) routes
        identically — a warmed/restored cluster keeps its layout."""
        sql = sql_region("SUM(lo_revenue) AS r", "d_year = 1993")
        a = canon.canonicalize(sql, scope="x")
        b = SQLCanonicalizer(ssb_small.schema).canonicalize(sql, scope="x")
        assert a is not b
        assert family_hash(a) == family_hash(b)

    def test_scopes_spread_over_shards(self, ssb_small, canon):
        cluster = mk_cluster(ssb_small, 4)
        idxs = {cluster.shard_index(
            canon.canonicalize(sql_region("SUM(lo_revenue) AS r"),
                               scope=f"s{i}")) for i in range(32)}
        assert len(idxs) > 1  # 32 scopes cannot all collapse onto one shard

    def test_register_tenant_shards_builds_cluster(self, ssb_small):
        svc = mk_service(ssb_small, shards=4)
        cache = svc.tenant("t").cache
        assert isinstance(cache, CacheCluster)
        assert cache.num_shards == 4
        # the template's level_mapper reached every shard
        assert all(s.cache.level_mapper is not None for s in cache.shards())


# ------------------------------------------------- differential oracle


class TestDifferentialOracle:
    def _trace(self, wl, shards):
        from benchmarks.bench_refresh import make_delta

        be = OlapExecutor(wl.dataset, impl="numpy")
        svc = CacheService()
        svc.register_tenant(
            "t", schema=wl.schema, backend=be,
            cache=SemanticCache(wl.schema,
                                level_mapper=wl.dataset.level_mapper()),
            nl=MemoizedNL(SimulatedLLM(wl.vocab, model="oracle")),
            shards=shards)
        m = "SUM(lo_revenue) AS rev, COUNT(*) AS n"
        sqls = [sql_region(m, f"d_year = {y}") for y in (1992, 1993)]
        fine = sql_region(m, "d_year = 1994", "c_region, c_nation")
        coarse = sql_region(m, "d_year = 1994")
        out = []

        def rec(results):
            for r in results:
                rows = None
                if r.table is not None:
                    rows = sorted(zip(*[map(str, r.table.columns[n])
                                        for n in r.table.names]))
                out.append((r.status, rows))

        rec(svc.submit_batch([QueryRequest(sql=q, tenant="t")
                              for q in sqls + [fine, sqls[0]]]))
        rec(svc.submit_batch(
            [QueryRequest(sql=coarse, tenant="t"),
             QueryRequest(nl="total revenue by region", tenant="t",
                          now=dt.date(1995, 6, 1))]))
        rep = svc.advance_snapshot(
            "t", "snap1",
            delta=make_delta(wl.dataset, 60, np.random.default_rng(5)))
        out.append(("refresh", rep.refreshed, rep.recomputed, rep.dropped,
                    rep.unaffected))
        rec(svc.submit_batch([QueryRequest(sql=q, tenant="t")
                              for q in sqls + [coarse]]))
        cs = svc.tenant("t").cache.stats
        out.append(("stats", cs.hits_exact, cs.hits_rollup, cs.misses,
                    cs.stores, cs.refreshes))
        return out

    def test_shards4_equals_shards1_and_plain(self):
        """Identical hit/miss/derivation outcomes, identical tables, identical
        cache counters for a mixed SQL/NL workload with derivations and a
        snapshot advance — run on fresh datasets (the delta mutates them)."""
        from repro.workloads import ssb

        t_plain = self._trace(ssb.build(n_fact=4000, seed=0), None)
        t_one = self._trace(ssb.build(n_fact=4000, seed=0), 1)
        t_four = self._trace(ssb.build(n_fact=4000, seed=0), 4)
        assert t_plain == t_one
        assert t_plain == t_four


# -------------------------------------------------------- single flight


class TestSingleFlight:
    def _storm(self, wl, backend, n_threads, sql, **tenant_kw):
        svc = CacheService()
        svc.register_tenant("t", schema=wl.schema, backend=backend, shards=4,
                            **tenant_kw)
        results = [None] * n_threads
        errors = [None] * n_threads
        barrier = threading.Barrier(n_threads)

        def worker(i):
            barrier.wait()
            try:
                results[i] = svc.submit(QueryRequest(sql=sql, tenant="t"))
            except Exception as e:  # noqa: BLE001 — recorded for assertions
                errors[i] = e

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return svc, results, errors

    def test_cold_storm_executes_once(self, ssb_small):
        """K threads issuing the same cold signature trigger exactly one
        executor call; every thread receives the identical table."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.05)
        svc, results, errors = self._storm(
            ssb_small, be, 8, sql_region("SUM(lo_revenue) AS r"))
        assert errors == [None] * 8
        assert be.calls == 1
        assert all(r.status == "miss" for r in results)
        ref = results[0].table
        for r in results[1:]:
            assert r.table.equals(ref)
        t = svc.tenant("t")
        assert t.stats.coalesced_misses == 7
        assert t.stats.backend_executions == 1
        assert len(t.cache) == 1  # one store; followers never double-store
        assert svc.submit(
            QueryRequest(sql=sql_region("SUM(lo_revenue) AS r"),
                         tenant="t")).status == "hit_exact"

    def test_leader_failure_releases_followers(self, ssb_small):
        """A crashed leader must not strand followers: the flight is failed,
        waiters wake and execute the query themselves.  The leader itself
        resolves to a *structured* error result — containment means no raw
        exception ever escapes the pipeline."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.05, fail_first=True)
        svc, results, errors = self._storm(
            ssb_small, be, 4, sql_region("COUNT(*) AS n"),
            # one attempt: with the default retry budget the leader would
            # simply recover on its second try (fail_first fails only once)
            resilience=ResiliencePolicy(execute_attempts=1))
        assert errors == [None] * 4  # no raw exceptions, ever
        failed = [r for r in results if r.status == "error"]
        served = [r for r in results if r.status == "miss"]
        assert len(failed) == 1  # the leader reports its backend error
        assert failed[0].error is not None
        assert failed[0].error.stage == "execute"
        assert failed[0].table is None
        assert len(served) == 3
        assert all(r.table is not None for r in served)

    def test_leader_failure_with_retries_recovers(self, ssb_small):
        """Default policy: a transient first-call failure is retried with
        backoff and the whole storm succeeds — one visible retry, zero
        errors."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.05, fail_first=True)
        svc, results, errors = self._storm(
            ssb_small, be, 4, sql_region("COUNT(*) AS n"))
        assert errors == [None] * 4
        assert all(r.status == "miss" and r.table is not None for r in results)
        t = svc.tenant("t")
        assert t.stats.retries >= 1
        assert t.stats.failures == 0

    def test_leader_death_chaos_followers_fall_back(self, ssb_small, canon):
        """Injected ``flight.leader_death`` (the chaos harness's post-compute,
        pre-publish crash): the leader resolves to a structured error, its
        flight is failed, and followers that coalesced onto it self-execute —
        producing tables bit-identical to a direct backend run.  Zero false
        hits: the cache ends up holding the *correct* table."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.2)
        svc = CacheService()
        svc.register_tenant("t", schema=ssb_small.schema, backend=be, shards=4,
                            resilience=ResiliencePolicy(serve_stale=False))
        sql = sql_region("SUM(lo_revenue) AS r")
        out = {}

        def leader():
            out["leader"] = svc.submit(QueryRequest(sql=sql, tenant="t"))

        def follower(i):
            time.sleep(0.05)  # join the flight while the leader is mid-execute
            out[i] = svc.submit(QueryRequest(sql=sql, tenant="t"))

        with faults.scoped("flight.leader_death:1.0"):
            # rate 1.0 is still deterministic here: the death point only
            # draws for flight-*leader* groups, and the follower fallbacks
            # run leaderless (their flight already failed)
            ts = [threading.Thread(target=leader)] + [
                threading.Thread(target=follower, args=(i,)) for i in range(2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()

        dead = out["leader"]
        assert dead.status == "error" and dead.table is None
        assert dead.error is not None and dead.error.kind == "fault"
        assert "flight.leader_death" in dead.error.message
        ref = OlapExecutor(ssb_small.dataset, impl="numpy").execute(
            canon.canonicalize(sql))
        for i in range(2):
            r = out[i]
            assert r.status == "miss"
            assert "execute:flight_fallback" in r.provenance
            assert r.table is not None and r.table.equals(ref)
        # the fallback stored a correct table: the next request is a true hit
        after = svc.submit(QueryRequest(sql=sql, tenant="t"))
        assert after.status == "hit_exact" and after.table.equals(ref)
        assert be.calls == 3  # leader + two independent fallbacks, no more

    def test_leader_death_storm_zero_false_hits(self, ssb_small, canon):
        """Chaos storm at 50% leader-death rate: containment holds (no raw
        exceptions), every non-error response carries the bit-identical
        correct table — degraded availability never becomes a wrong answer."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.02)
        with faults.scoped("flight.leader_death:0.5:5"):
            svc, results, errors = self._storm(
                ssb_small, be, 8, sql_region("COUNT(*) AS n"),
                resilience=ResiliencePolicy(serve_stale=False))
        assert errors == [None] * 8
        ref = OlapExecutor(ssb_small.dataset, impl="numpy").execute(
            canon.canonicalize(sql_region("COUNT(*) AS n")))
        statuses = {r.status for r in results}
        assert statuses <= {"miss", "hit_exact", "error"}
        for r in results:
            if r.status == "error":
                assert r.table is None and "leader_death" in r.error.message
            else:
                assert r.table is not None and r.table.equals(ref)
        assert any(r.status != "error" for r in results)  # someone got served

    def test_flight_api_joins_and_completes(self, ssb_small, canon, backend):
        cluster = mk_cluster(ssb_small, 2)
        sig = canon.canonicalize(sql_region("SUM(lo_revenue) AS r"))
        lr, flight, leader = cluster.lookup_or_flight(sig)
        assert lr.status == "miss" and leader and not flight.done
        lr2, flight2, leader2 = cluster.lookup_or_flight(sig)
        assert flight2 is flight and not leader2  # joined, not re-registered
        assert cluster.inflight() == 1
        table = backend.execute(sig)
        cluster.complete_flight(flight, table)
        assert flight.ok and flight.table is table
        assert cluster.inflight() == 0
        # a new miss after completion starts a fresh flight
        sig_b = canon.canonicalize(sql_region("COUNT(*) AS n"))
        _, fb, lb = cluster.lookup_or_flight(sig_b)
        assert lb and fb is not flight
        cluster.fail_flight(fb, RuntimeError("abandoned"))
        assert fb.done and not fb.ok

    def test_flight_completes_when_flightless_request_shares_key(self, ssb_small):
        """Regression: a refresh=True request (skips lookup, carries no
        flight) batched before a normal request with the same signature used
        to leave the normal request's flight at group[1:], where it was never
        completed — cross-thread followers then fell back and re-executed.
        The flight must complete and followers must coalesce."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.2)
        svc = CacheService()
        svc.register_tenant("t", schema=ssb_small.schema, backend=be, shards=4)
        sql = sql_region("SUM(lo_revenue) AS r")
        follower_result = []

        def leader_batch():
            follower_result.append(svc.submit_batch([
                QueryRequest(sql=sql, tenant="t", refresh=True),
                QueryRequest(sql=sql, tenant="t"),
            ]))

        def follower():
            time.sleep(0.05)  # join while the leader batch is stalled
            follower_result.append(svc.submit(QueryRequest(sql=sql, tenant="t")))

        ts = [threading.Thread(target=leader_batch),
              threading.Thread(target=follower)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert be.calls == 1  # the follower coalesced instead of re-executing
        assert svc.tenant("t").stats.coalesced_misses == 1
        assert svc.tenant("t").cache.inflight() == 0

    def test_single_flight_disabled(self, ssb_small, canon):
        cluster = mk_cluster(ssb_small, 2, single_flight=False)
        sig = canon.canonicalize(sql_region("SUM(lo_revenue) AS r"))
        lr, flight, leader = cluster.lookup_or_flight(sig)
        assert lr.status == "miss" and flight is None and not leader


# ------------------------------------------------------------- rebalance


class TestRebalance:
    def _fill(self, cluster, canon, backend, years=(1992, 1993, 1994, 1995)):
        sigs = []
        for scope in ("a", "b", "c"):
            for m in ("SUM(lo_revenue) AS r", "COUNT(*) AS n"):
                for y in years:
                    sigs.append(canon.canonicalize(
                        sql_region(m, f"d_year = {y}"), scope=scope))
        for s in sigs:
            cluster.put(s, backend.execute(s))
        return sigs

    def test_add_remove_preserves_entries_and_hits(self, ssb_small, canon,
                                                   backend):
        cluster = mk_cluster(ssb_small, 2)
        sigs = self._fill(cluster, canon, backend)
        tables = {s.key(): cluster.entry(s.key()).table for s in sigs}
        before_keys = sorted(cluster.keys())
        stores_before = cluster.stats.stores

        assert cluster.add_shard() == 3
        assert sorted(cluster.keys()) == before_keys
        for s in sigs:
            lr = cluster.lookup(s)
            assert lr.status == "hit_exact"
            assert lr.table is tables[s.key()]  # the same object migrated

        assert cluster.remove_shard() == 2
        assert cluster.remove_shard() == 1
        assert sorted(cluster.keys()) == before_keys
        for s in sigs:
            assert cluster.lookup(s).status == "hit_exact"
        # counters never go backwards across topology changes
        assert cluster.stats.stores == stores_before
        assert cluster.stats.bytes_cached == cluster.total_bytes()
        with pytest.raises(ValueError):
            cluster.remove_shard()

    def test_derivations_survive_migration(self, ssb_small, canon, backend):
        cluster = mk_cluster(ssb_small, 1)
        fine = canon.canonicalize(
            sql_region("SUM(lo_revenue) AS r", "d_year = 1994",
                       "c_region, c_nation"))
        cluster.put(fine, backend.execute(fine))
        coarse = canon.canonicalize(sql_region("SUM(lo_revenue) AS r",
                                               "d_year = 1994"))
        assert cluster.lookup(coarse).status == "hit_rollup"
        for n in (2, 5, 3, 1):
            cluster.set_shards(n)
            assert cluster.lookup(coarse).status == "hit_rollup"

    def test_migrated_entry_leaves_no_stale_index(self, ssb_small, canon,
                                                  backend):
        """Tier-2 index membership is fully cleaned up by migration: the
        source shard retains no trace, and dropping the entry on its new home
        shard makes derivation probes miss everywhere."""
        cluster = mk_cluster(ssb_small, 2)
        fine = canon.canonicalize(
            sql_region("SUM(lo_revenue) AS r", "d_year = 1994",
                       "c_region, c_nation"))
        key = cluster.put(fine, backend.execute(fine))
        old_home = cluster.shard_for(fine)
        # grow until the family re-routes to a different shard index
        for n in (3, 4, 5, 6, 7):
            cluster.set_shards(n)
            if cluster.shard_index(fine) != old_home.index:
                break
        else:
            pytest.fail("family never re-routed while growing to 7 shards")
        new_home = cluster.shard_for(fine)
        assert new_home is not old_home
        for shard in cluster.shards():
            if shard is new_home:
                continue
            assert not shard.contains(key)
            assert key not in shard.cache._index_of
            assert key not in shard.cache._seq_of
            assert all(key not in b.order
                       for b in shard.cache._by_measures.values())
        coarse = canon.canonicalize(sql_region("SUM(lo_revenue) AS r",
                                               "d_year = 1994"))
        assert cluster.lookup(coarse).status == "hit_rollup"
        assert cluster.drop(key)
        assert cluster.lookup(coarse).status == "miss"
        assert cluster.entry(key) is None

    def test_evicted_entry_never_serves_derivation(self, ssb_small, canon,
                                                   backend):
        """Eviction regression (unsharded core path): once the LRU pushes a
        roll-up source out, derivation probes must miss — no ghost candidates
        in any index tier."""
        cache = SemanticCache(ssb_small.schema, capacity=1,
                              level_mapper=ssb_small.dataset.level_mapper())
        fine = canon.canonicalize(
            sql_region("SUM(lo_revenue) AS r", "d_year = 1994",
                       "c_region, c_nation"))
        key = cache.put(fine, backend.execute(fine))
        coarse = canon.canonicalize(sql_region("SUM(lo_revenue) AS r",
                                               "d_year = 1994"))
        assert cache.lookup(coarse).status == "hit_rollup"
        other = canon.canonicalize(sql_region("COUNT(*) AS n"))
        cache.put(other, backend.execute(other))  # capacity=1: evicts `fine`
        assert cache.stats.evictions == 1
        assert cache.lookup(coarse).status == "miss"
        assert key not in cache._index_of and key not in cache._seq_of
        assert all(key not in b.order for b in cache._by_measures.values())

    def test_lru_order_survives_rebalance(self, ssb_small, canon, backend):
        """Recency is carried by global stamps: after shrinking to one shard,
        evictions hit the *least recently touched* entry across the whole
        pre-migration population, not an artifact of migration order."""
        cluster = mk_cluster(ssb_small, 3)
        sigs = self._fill(cluster, canon, backend, years=(1992, 1993))
        victim, kept = sigs[0], sigs[1:]
        for s in kept:  # touch everything except the victim
            assert cluster.lookup(s).status == "hit_exact"
        cluster.set_shards(1)
        shard = cluster.shards()[0]
        shard.cache.capacity = len(sigs) - 1
        shard.cache._enforce_capacity()
        assert cluster.entry(victim.key()) is None
        assert all(cluster.entry(s.key()) is not None for s in kept)


# ------------------------------------------------------- byte accounting


def _table(n_rows, n_cols=1):
    return ResultTable({f"c{i}": np.arange(n_rows, dtype=np.float64)
                        for i in range(n_cols)})


class TestByteAccounting:
    def _sigs(self, canon, n):
        return [canon.canonicalize(sql_region("SUM(lo_revenue) AS r",
                                              f"d_year = {1992 + i}"))
                for i in range(n)]

    def test_capacity_bytes_evicts_lru(self, ssb_small, canon):
        cache = SemanticCache(ssb_small.schema, capacity_bytes=3000)
        sigs = self._sigs(canon, 4)
        for s in sigs[:3]:
            cache.put(s, _table(125))  # 1000 bytes each
        assert len(cache) == 3
        assert cache.stats.bytes_cached == 3000 == cache.total_bytes()
        assert cache.stats.bytes_evicted == 0
        cache.put(sigs[3], _table(125))  # over budget: LRU out
        assert len(cache) == 3
        assert cache.entry(sigs[0].key()) is None
        assert cache.stats.bytes_cached == 3000
        assert cache.stats.bytes_evicted == 1000
        assert cache.stats.evictions == 1

    def test_entry_count_and_bytes_budgets_compose(self, ssb_small, canon):
        cache = SemanticCache(ssb_small.schema, capacity=10,
                              capacity_bytes=2000)
        for s in self._sigs(canon, 4):
            cache.put(s, _table(125))
        assert len(cache) == 2  # bytes budget binds before the entry budget

    def test_overwrite_and_refresh_track_bytes(self, ssb_small, canon):
        cache = SemanticCache(ssb_small.schema)
        (sig,) = self._sigs(canon, 1)
        key = cache.put(sig, _table(100))
        assert cache.stats.bytes_cached == 800
        cache.put(sig, _table(200))  # overwrite with a bigger table
        assert cache.stats.bytes_cached == 1600
        cache.refresh_entry(key, _table(50), "snap1")
        assert cache.stats.bytes_cached == 400
        assert cache.entry(key).table_nbytes == 400
        cache.drop(key)
        assert cache.stats.bytes_cached == 0

    def test_refresh_growth_enforces_byte_budget(self, ssb_small, canon):
        """Regression: delta merges grow cached tables in place, and the
        growth must evict LRU just like a put would."""
        cache = SemanticCache(ssb_small.schema, capacity_bytes=2000)
        sigs = self._sigs(canon, 2)
        keys = [cache.put(s, _table(100)) for s in sigs]  # 800 bytes each
        cache.refresh_entry(keys[1], _table(200), "snap1")  # grows to 1600
        assert cache.stats.bytes_cached <= 2000
        assert cache.entry(keys[0]) is None  # LRU evicted to make room
        assert cache.stats.evictions == 1

    def test_cluster_splits_byte_budget(self, ssb_small):
        cluster = CacheCluster(ssb_small.schema, shards=4,
                               capacity_bytes=4000)
        assert all(s.cache.capacity_bytes == 1000 for s in cluster.shards())
        one = CacheCluster(ssb_small.schema, shards=1, capacity_bytes=4000)
        assert one.shards()[0].cache.capacity_bytes == 4000

    def test_stats_surface_bytes(self, ssb_small, canon):
        svc = mk_service(ssb_small, shards=2)
        svc.submit(QueryRequest(sql=sql_region("SUM(lo_revenue) AS r"),
                                tenant="t"))
        d = svc.stats("t")
        assert d["cache"]["bytes_cached"] > 0
        assert d["cache"]["bytes_evicted"] == 0
        assert d["cluster"]["shards"] == 2
        assert len(d["cluster"]["by_shard"]) == 2
        json.dumps(d)  # the whole stats payload stays serializable


# --------------------------------------------------- TenantStats threading


class TestTenantStatsConcurrency:
    def test_concurrent_bumps_and_reservoirs_are_exact(self):
        from repro.service import TenantStats

        stats = TenantStats()
        n_threads, n_iter = 8, 2000

        def worker(tid):
            for i in range(n_iter):
                stats.bump(requests=1, stores=1, backend_executions=2)
                stats.record_stage_timings({"lookup": float(i % 7),
                                            "execute": 1.0})
                if i % 256 == 0:
                    stats.stage_percentiles()  # concurrent reader

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert stats.requests == n_threads * n_iter
        assert stats.stores == n_threads * n_iter
        assert stats.backend_executions == 2 * n_threads * n_iter
        pct = stats.stage_percentiles()
        assert set(pct) == {"lookup", "execute"}
        json.dumps(stats.to_dict())

    def test_concurrent_service_traffic_counts_consistently(self, ssb_small):
        """8 threads of mixed hit/miss traffic through one sharded tenant:
        every response is well-formed and the request counter is exact."""
        svc = mk_service(ssb_small, shards=4)
        sqls = [sql_region("SUM(lo_revenue) AS r", f"d_year = {y}")
                for y in (1992, 1993, 1994, 1995)]
        n_threads, per_thread = 8, 12
        errors = []

        def worker(tid):
            try:
                for i in range(per_thread):
                    r = svc.submit(QueryRequest(
                        sql=sqls[(tid + i) % len(sqls)], tenant="t"))
                    assert r.status in ("miss", "hit_exact")
                    assert r.table is not None
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        t = svc.tenant("t")
        assert t.stats.requests == n_threads * per_thread
        # every request was served: hits + misses + coalesced add up
        cs = t.cache.stats
        assert cs.lookups + t.stats.coalesced_misses >= n_threads * per_thread


# ---------------------------------------------------- runtime lock sanitizer


class TestSanitizer:
    """Re-run the heaviest concurrency paths with REPRO_SANITIZE=1: every
    make_lock becomes a SanitizedLock that records acquisition order and
    raises on a demonstrated inversion or a blocking wait under a held lock.
    Services must be constructed *inside* the fixture scope — make_lock
    checks the env at call time."""

    @pytest.fixture()
    def sanitized(self, monkeypatch):
        from repro.analysis import sanitizer
        sanitizer.reset()
        monkeypatch.setenv(sanitizer.ENV_FLAG, "1")
        yield sanitizer
        sanitizer.reset()

    def test_single_flight_storm_sanitized(self, ssb_small, sanitized):
        """The flight-wait path holds only the shared read gate (never a
        shard lock) while blocking on the leader — the sanitizer proves it."""
        be = CountingBackend(OlapExecutor(ssb_small.dataset, impl="numpy"),
                             stall_s=0.05)
        svc = CacheService()
        svc.register_tenant("t", schema=ssb_small.schema, backend=be,
                            shards=4)
        sql = sql_region("SUM(lo_revenue) AS r")
        n = 8
        results = [None] * n
        errors = [None] * n
        barrier = threading.Barrier(n)

        def worker(i):
            barrier.wait()
            try:
                results[i] = svc.submit(QueryRequest(sql=sql, tenant="t"))
            except Exception as e:  # noqa: BLE001
                errors[i] = e

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == [None] * n
        assert be.calls == 1
        assert sanitized.violations() == []
        # at least one real edge was observed under load
        assert sanitized.observed_edges()

    def test_mixed_traffic_and_refresh_sanitized(self, ssb_small, sanitized):
        """Mixed hit/miss traffic racing a snapshot advance: the write gate
        nests over shard locks in one consistent order, no violations."""
        svc = mk_service(ssb_small, shards=4)
        sqls = [sql_region("SUM(lo_revenue) AS r", f"d_year = {y}")
                for y in (1992, 1993, 1994, 1995)]
        errors = []

        def worker(tid):
            try:
                for i in range(8):
                    r = svc.submit(QueryRequest(
                        sql=sqls[(tid + i) % len(sqls)], tenant="t"))
                    assert r.status in ("miss", "hit_exact")
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        time.sleep(0.01)
        svc.advance_snapshot("t", snapshot_id="s2", refresh=False)
        for t in threads:
            t.join()
        assert errors == []
        assert sanitized.violations() == []
        edges = sanitized.observed_edges()
        held = set(edges) | {b for bs in edges.values() for b in bs}
        assert "CacheShard.lock" in held

    def test_rebalance_under_sanitizer(self, ssb_small, canon, backend,
                                       sanitized):
        """set_shards acquires every shard lock (in index order) under the
        topology lock — legal only because CacheShard.lock is registered
        self-ordered; the sanitizer accepts it and records the edge."""
        cluster = mk_cluster(ssb_small, 4)
        for y in (1992, 1993, 1994, 1995):
            sig = canon.canonicalize(
                sql_region("SUM(lo_revenue) AS r", f"d_year = {y}"))
            cluster.put(sig, backend.execute(sig))
        n_before = len(cluster)
        cluster.set_shards(2)
        cluster.set_shards(4)
        assert len(cluster) == n_before
        assert sanitized.violations() == []
        assert "CacheShard.lock" in sanitized.observed_edges().get(
            "CacheCluster._topology_lock", set())
