"""End-to-end behaviour tests for the paper's system: the headline claims of
Table 1 / RQ2 / RQ4 hold on a reduced-scale run, and the serving substrate's
production pieces (engine, mesh plan, configs) are wired together."""
import collections

import pytest

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,
                        SemanticCacheMiddleware, SimulatedLLM)
from repro.olap.executor import OlapExecutor

QUAL = ("customer region", "supplier region", "customer city", "supplier city",
        "customer nation", "supplier nation", "pickup zone", "dropoff zone",
        "pickup borough", "dropoff borough")


def run_workload(wl, order="sequential", model="gpt-4o-mini", **cache_kw):
    backend = OlapExecutor(wl.dataset, impl="numpy")
    cache = SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(), **cache_kw)
    mw = SemanticCacheMiddleware(
        wl.schema, backend, cache, nl=MemoizedNL(SimulatedLLM(wl.vocab, model=model)),
        policy=SafetyPolicy.balanced(wl.spatial_ambiguous, qualified=QUAL))
    statuses = collections.Counter()
    queries = wl.queries(sql_variants=8, nl_paraphrases=5, order=order)
    for q in queries:
        r = mw.query_sql(q.text) if q.kind == "sql" else mw.query_nl(q.text)
        statuses[r.status] += 1
    hits = sum(v for k, v in statuses.items() if k.startswith("hit"))
    return hits / len(queries), statuses, backend, mw


class TestHeadlineClaims:
    def test_intent_caching_beats_text_and_ast(self, ssb_small):
        """Table 1's ordering: LLMSigCache > ASTCache > TextCache."""
        import benchmarks.common as bc

        queries = ssb_small.queries(sql_variants=8, nl_paraphrases=5)
        text = bc.run_method("text", ssb_small, queries)
        ast = bc.run_method("ast", ssb_small, queries)
        sig = bc.run_method("llmsig", ssb_small, queries, audit_false_hits=True)
        assert text.hit_rate < ast.hit_rate < sig.hit_rate
        assert sig.false_hits == 0
        assert sig.hit_rate > 0.85

    def test_backend_savings(self, tlc_small):
        hit_rate, _, backend, _ = run_workload(tlc_small)
        total = len(tlc_small.queries(sql_variants=8, nl_paraphrases=5))
        assert hit_rate > 0.85
        assert backend.executions < 0.2 * total  # >80% backend saving

    def test_all_three_workloads_clean(self, ssb_small, tlc_small, tpcds_small):
        for wl in (ssb_small, tlc_small, tpcds_small):
            hit_rate, statuses, _, mw = run_workload(wl)
            assert hit_rate > 0.80, (wl.name, statuses)

    def test_rq4_derivation_uplift(self, ssb_small):
        from repro.workloads import hierarchical

        stream = hierarchical.build_stream(12)

        def run(deriv):
            backend = OlapExecutor(ssb_small.dataset, impl="numpy")
            cache = SemanticCache(ssb_small.schema, enable_rollup=deriv,
                                  enable_filterdown=deriv,
                                  level_mapper=ssb_small.dataset.level_mapper())
            mw = SemanticCacheMiddleware(ssb_small.schema, backend, cache)
            hits = sum(mw.query_sql(q.text).hit for q in stream)
            return hits / len(stream)

        off, on = run(False), run(True)
        assert on >= off + 0.3  # the paper's 37% -> 80% uplift shape
        assert on >= 0.75


class TestServingSubstrate:
    def test_production_mesh_shapes(self):
        import jax

        from repro.launch.mesh import make_production_mesh

        if len(jax.devices()) < 512:
            pytest.skip("production mesh needs 512 (placeholder) devices; "
                        "covered by launch/dryrun.py")
        m = make_production_mesh()
        assert dict(zip(m.axis_names, m.devices.shape)) == {"data": 16, "model": 16}
        m = make_production_mesh(multi_pod=True)
        assert dict(zip(m.axis_names, m.devices.shape)) == {
            "pod": 2, "data": 16, "model": 16}

    def test_input_specs_cover_every_cell(self):
        from repro.configs.registry import ASSIGNED, SUBQUADRATIC, get
        from repro.configs.shapes import SHAPES, input_specs

        cells = 0
        for arch in ASSIGNED:
            for sname, spec in SHAPES.items():
                if sname == "long_500k" and arch not in SUBQUADRATIC:
                    continue
                ins = input_specs(get(arch), spec)
                assert ins, (arch, sname)
                cells += 1
        assert cells == 32  # 10x3 + 2 long-context cells

    def test_dryrun_results_green(self):
        """The committed dry-run artifact must show every baseline cell ok."""
        import json
        import os

        path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
        if not os.path.exists(path):
            pytest.skip("dry-run artifact not generated yet")
        with open(path) as f:
            res = json.load(f)
        base = {k: v for k, v in res.items() if len(k.split("|")) == 3}
        assert len(base) == 64
        assert all(v.get("status") == "ok" for v in base.values())
