"""Training loop, checkpoint/restart fault tolerance, elastic re-mesh,
gradient compression, and ZeRO-1 spec logic."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import reduced
from repro.training.checkpoint import prune_old, restore_latest, save_checkpoint
from repro.training.data import BatchIterator, build_pairs
from repro.training.optimizer import (AdamWConfig, adamw_update, compress_grads,
                                      decompress_grads, init_opt_state, zero1_spec)
from repro.training.tokenizer import build_tokenizer
from repro.training.train_lib import TrainConfig, train


@pytest.fixture(scope="module")
def tiny_setup(ssb_small):
    cfg = dataclasses.replace(reduced("canonicalizer-100m"), vocab=4096)
    tok = build_tokenizer([ssb_small])
    pairs = build_pairs([ssb_small], paraphrases_per_intent=6)
    return cfg, tok, pairs


class TestTrainLoop:
    def test_loss_decreases(self, tiny_setup, tmp_path):
        cfg, tok, pairs = tiny_setup
        batches = BatchIterator(pairs, tok, batch=4, seq_len=96)
        out = train(cfg, TrainConfig(steps=30, log_every=10), batches,
                    key=jax.random.PRNGKey(0), log=lambda s: None)
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0]
        assert np.isfinite(losses[-1])

    def test_restart_resumes_from_checkpoint(self, tiny_setup, tmp_path):
        cfg, tok, pairs = tiny_setup
        batches = BatchIterator(pairs, tok, batch=2, seq_len=64)
        ck = str(tmp_path / "ck")
        # run 1: 10 steps with checkpoint every 5
        train(cfg, TrainConfig(steps=10, ckpt_dir=ck, ckpt_every=5, log_every=100),
              batches, key=jax.random.PRNGKey(0), log=lambda s: None)
        # run 2 ("after failure"): resumes, doesn't start from scratch
        msgs = []
        train(cfg, TrainConfig(steps=12, ckpt_dir=ck, ckpt_every=5, log_every=100),
              batches, key=jax.random.PRNGKey(0), log=msgs.append)
        assert any("resumed from step 9" in m for m in msgs)

    def test_grad_compression_trains(self, tiny_setup):
        cfg, tok, pairs = tiny_setup
        batches = BatchIterator(pairs, tok, batch=2, seq_len=64)
        out = train(cfg, TrainConfig(steps=12, grad_compression=True, log_every=5),
                    batches, key=jax.random.PRNGKey(0), log=lambda s: None)
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0]

    def test_microbatching_matches_full_batch_loss_scale(self, tiny_setup):
        cfg, tok, pairs = tiny_setup
        batches = BatchIterator(pairs, tok, batch=4, seq_len=64)
        out = train(cfg, TrainConfig(steps=3, microbatches=2, log_every=1),
                    batches, key=jax.random.PRNGKey(0), log=lambda s: None)
        assert np.isfinite(out["history"][-1]["loss"])


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,))}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
        restored, step, extra = restore_latest(str(tmp_path), tree)
        assert step == 7 and extra == {"note": "x"}
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_corrupt_latest_falls_back(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 1, tree)
        save_checkpoint(str(tmp_path), 2, tree)
        # corrupt newest: truncate one array file
        newest = os.path.join(str(tmp_path), "step_00000002")
        victim = next(f for f in os.listdir(newest) if f.endswith(".npy"))
        with open(os.path.join(newest, victim), "wb") as f:
            f.write(b"garbage")
        _, step, _ = restore_latest(str(tmp_path), tree)
        assert step == 1  # fell back to the older valid checkpoint

    def test_tmp_dir_never_restored(self, tmp_path):
        tree = self._tree()
        save_checkpoint(str(tmp_path), 3, tree)
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        _, step, _ = restore_latest(str(tmp_path), tree)
        assert step == 3

    def test_prune(self, tmp_path):
        tree = self._tree()
        for s in range(5):
            save_checkpoint(str(tmp_path), s, tree)
        prune_old(str(tmp_path), keep=2)
        left = sorted(d for d in os.listdir(str(tmp_path)))
        assert left == ["step_00000003", "step_00000004"]


class TestElastic:
    def test_plan_remesh(self):
        from repro.distributed.elastic import plan_remesh

        p = plan_remesh(512, 16)
        assert p.shape == (2, 16, 16) and p.axis_names == ("pod", "data", "model")
        p = plan_remesh(496, 16)  # lost a node: 31 data rows, no pod split
        assert p.shape == (31, 16)
        with pytest.raises(ValueError):
            plan_remesh(8, 16)

    def test_elastic_restart_controller(self, tmp_path):
        from repro.distributed.elastic import DeviceLossError, ElasticController

        calls = []

        def run_fn(mesh):
            calls.append(tuple(mesh.devices.shape))
            return {"ok": True}

        def injector(restart):
            if restart == 0:
                raise DeviceLossError([])  # lose nothing, just force restart

        ctl = ElasticController(run_fn, model_parallel=1)
        out = ctl.run(fail_injector=injector)
        assert out["ok"] and ctl.restarts == 1

    def test_straggler_policy(self):
        from repro.distributed.elastic import StragglerPolicy

        pol = StragglerPolicy(deadline_factor=2.0, strikes_to_exclude=3)
        for _ in range(10):
            pol.observe(0, 1.0)
        for _ in range(3):
            pol.observe(7, 10.0)  # persistent straggler
        assert pol.excluded_hosts() == [7]


class TestOptimizer:
    def test_zero1_spec_adds_data_axis(self):
        s = zero1_spec(P(None, "model"), (1024, 64), ("data",), 16)
        assert s == P("data", "model")
        # nothing divisible -> unchanged
        s = zero1_spec(P(None,), (7,), ("data",), 16)
        assert s == P(None)

    def test_compression_error_feedback(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
        q, scales, resid = compress_grads(g)
        deq = decompress_grads(q, scales)
        err = float(jnp.abs(deq["w"] - g["w"]).max())
        assert err < float(scales["w"]) + 1e-6  # quantization bound
        np.testing.assert_allclose(
            np.asarray(resid["w"]), np.asarray(g["w"] - deq["w"]), rtol=1e-5, atol=1e-7)

    def test_adamw_step_moves_params(self):
        p = {"w": jnp.ones((8, 8), jnp.float32)}
        g = {"w": jnp.full((8, 8), 0.5, jnp.float32)}
        st = init_opt_state(p)
        newp, newst, gnorm = adamw_update(AdamWConfig(lr=1e-2, warmup_steps=1), p, g, st)
        assert float(jnp.abs(newp["w"] - p["w"]).max()) > 0
        assert int(newst["step"]) == 1
