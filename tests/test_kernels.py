"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp oracle
across shapes and dtypes, as required for every kernel in kernels/."""
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


# ------------------------------------------------------------------ seg_agg


@pytest.mark.parametrize("n,m,g", [(512, 1, 16), (1000, 3, 17), (4096, 2, 512),
                                   (777, 4, 1000), (64, 1, 5)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_seg_agg(n, m, g, op):
    from repro.kernels.seg_agg.kernel import seg_agg_pallas
    from repro.kernels.seg_agg.ref import seg_agg_ref

    vals = rng.normal(size=(n, m)).astype(np.float32)
    ids = rng.integers(0, g, size=n).astype(np.int32)
    mask = (rng.random(n) > 0.3).astype(np.float32)
    ref = np.asarray(seg_agg_ref(vals, ids, mask, g, op))
    out = np.asarray(seg_agg_pallas(vals, ids, mask, g, op, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_seg_agg_dtypes():
    from repro.kernels.seg_agg.kernel import seg_agg_pallas
    from repro.kernels.seg_agg.ref import seg_agg_ref

    vals = rng.normal(size=(256, 2)).astype(np.float16).astype(np.float32)
    ids = rng.integers(0, 31, size=256).astype(np.int32)
    mask = np.ones(256, np.float32)
    for dt in (jnp.float32, jnp.bfloat16):
        v = jnp.asarray(vals, dt)
        ref = np.asarray(seg_agg_ref(v, ids, mask, 31, "sum"))
        out = np.asarray(seg_agg_pallas(v, ids, mask, 31, "sum", interpret=True))
        np.testing.assert_allclose(out, ref, rtol=1e-2, atol=1e-2)


# ------------------------------------------------------ seg_agg filter-fused


def _rand_bounds(p, k, lo=0, hi=10):
    """Random (P, K, 2) inclusive range bounds with some never-match pads."""
    b = np.empty((p, k, 2), np.float32)
    b[..., 0], b[..., 1] = np.inf, -np.inf
    for i in range(p):
        for j in range(rng.integers(1, k + 1)):
            a = rng.integers(lo, hi, size=2)
            b[i, j] = (min(a), max(a))
    return b


@pytest.mark.parametrize("n,m,g,p,k", [(512, 1, 16, 1, 1), (1000, 3, 17, 2, 2),
                                       (777, 2, 100, 3, 2), (64, 4, 5, 1, 4)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_seg_agg_fused(n, m, g, p, k, op):
    """Filter-fused kernel (mask built in-tile from bounds) vs fused oracle,
    interpret mode, including NaN-bearing values."""
    from repro.kernels.seg_agg.kernel import seg_agg_fused_pallas
    from repro.kernels.seg_agg.ref import seg_agg_fused_ref

    vals = rng.normal(size=(n, m)).astype(np.float32)
    vals[rng.random((n, m)) < 0.02] = np.nan
    ids = rng.integers(0, g, size=n).astype(np.int32)
    pred = rng.integers(0, 10, size=(n, p)).astype(np.float32)
    bounds = _rand_bounds(p, k)
    ref = np.asarray(seg_agg_fused_ref(vals, ids, pred, bounds, g, op))
    flat = np.concatenate([bounds[:, :, 0], bounds[:, :, 1]], axis=1)
    out = np.asarray(seg_agg_fused_pallas(vals, ids, pred, flat, g, op, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_bounds_mask_matches_numpy():
    from repro.kernels.seg_agg.ref import bounds_mask_ref

    n, p = 2000, 3
    pred = rng.integers(-5, 15, size=(n, p)).astype(np.float32)
    bounds = _rand_bounds(p, 2, lo=-5, hi=15)
    expect = np.ones(n, bool)
    for i in range(p):
        any_i = np.zeros(n, bool)
        for j in range(2):
            lo, hi = bounds[i, j]
            any_i |= (pred[:, i] >= lo) & (pred[:, i] <= hi)
        expect &= any_i
    got = np.asarray(bounds_mask_ref(pred, bounds))
    np.testing.assert_array_equal(got, expect)


def test_seg_agg_fused_empty_mask():
    """All-never bounds: sums are zero, mins stay at the identity."""
    from repro.kernels.seg_agg.ref import seg_agg_fused_ref

    vals = rng.normal(size=(128, 2)).astype(np.float32)
    ids = rng.integers(0, 7, size=128).astype(np.int32)
    pred = np.zeros((128, 1), np.float32)
    bounds = np.full((1, 1, 2), 0, np.float32)
    bounds[..., 0], bounds[..., 1] = np.inf, -np.inf
    out = np.asarray(seg_agg_fused_ref(vals, ids, pred, bounds, 7, "sum"))
    np.testing.assert_array_equal(out, np.zeros((7, 2), np.float32))
    out = np.asarray(seg_agg_fused_ref(vals, ids, pred, bounds, 7, "min"))
    np.testing.assert_array_equal(out, np.full((7, 2), np.inf, np.float32))


# ---------------------------------------------------- seg_agg batch entry


@pytest.mark.parametrize("impl,with_rect", [("xla", True), ("xla", False),
                                            ("interpret", False)])
def test_seg_agg_batch_blocks_matches_per_op(impl, with_rect):
    """The combined one-launch entry (shared masks/gathers for the SUM and
    MIN/MAX blocks) must agree with the per-op ``seg_agg_batch`` dispatch —
    keeps the two public batch paths from drifting apart."""
    from repro.kernels.seg_agg.ops import seg_agg_batch, seg_agg_batch_blocks

    n, g, s = 1000, 8, 5
    sum_vals = rng.normal(size=(n, 3)).astype(np.float32)
    mm_vals = rng.normal(size=(n, 2)).astype(np.float32)
    mm_vals[rng.integers(0, n, size=4), 0] = np.nan  # NaN-confinement contract
    ids = rng.integers(0, g, size=n).astype(np.int32)
    pred = rng.integers(0, 10, size=(n, 2)).astype(np.float32)
    bounds = np.stack([_rand_bounds(2, 2) for _ in range(s)])
    rect = None
    if with_rect:
        counts = np.bincount(ids, minlength=g)
        r = int(counts.max())
        order = np.argsort(ids, kind="stable")
        starts = np.concatenate([[0], np.cumsum(counts[:-1])])
        pos = np.arange(n) - starts[ids[order]]
        rect = np.full((g, r), n, np.int32)
        rect[ids[order], pos] = order
    sums, mm = seg_agg_batch_blocks(sum_vals, mm_vals, ids, pred, bounds, g,
                                    impl=impl, rect_idx=rect)
    ref_sums = np.asarray(seg_agg_batch(sum_vals, ids, pred, bounds, g,
                                        "sum", impl=impl))
    ref_mm = np.asarray(seg_agg_batch(mm_vals, ids, pred, bounds, g,
                                      "min", impl=impl))
    np.testing.assert_allclose(np.asarray(sums), ref_sums, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mm), ref_mm, rtol=1e-5, atol=1e-5)
    sums_only, none_mm = seg_agg_batch_blocks(sum_vals, None, ids, pred,
                                              bounds, g, impl=impl, rect_idx=rect)
    assert none_mm is None
    np.testing.assert_allclose(np.asarray(sums_only), ref_sums, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- flash attn


@pytest.mark.parametrize("b,h,hkv,s,dh", [
    (2, 4, 2, 256, 64), (1, 8, 1, 128, 32), (1, 4, 4, 100, 64), (2, 2, 2, 64, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, h, hkv, s, dh, causal):
    from repro.kernels.flash_attn.kernel import flash_attention_pallas
    from repro.kernels.flash_attn.ref import mha_ref

    q = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    k = rng.normal(size=(b, hkv, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, hkv, s, dh)).astype(np.float32)
    ref = np.asarray(mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal))
    out = np.asarray(flash_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        tq=64, tk=64, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attn.kernel import flash_attention_pallas
    from repro.kernels.flash_attn.ref import mha_ref

    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    ref = np.asarray(mha_ref(q, k, v)).astype(np.float32)
    out = np.asarray(flash_attention_pallas(q, k, v, tq=64, tk=64, interpret=True)).astype(np.float32)
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)


# -------------------------------------------------------------- decode attn


@pytest.mark.parametrize("b,h,hkv,s,dh,tk", [
    (2, 8, 2, 512, 64, 128), (1, 4, 1, 300, 128, 128), (3, 4, 4, 128, 32, 64),
])
def test_decode_attention(b, h, hkv, s, dh, tk):
    from repro.kernels.decode_attn.kernel import decode_attention_pallas
    from repro.kernels.decode_attn.ref import decode_attention_ref

    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k = rng.normal(size=(b, hkv, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, hkv, s, dh)).astype(np.float32)
    pos = rng.integers(1, s + 1, size=b).astype(np.int32)
    ref = np.asarray(decode_attention_ref(*map(jnp.asarray, (q, k, v, pos))))
    out = np.asarray(decode_attention_pallas(
        *map(jnp.asarray, (q, k, v, pos)), tk=tk, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_decode_attention_pos_mask_exact():
    """Entries beyond pos must not contribute at all."""
    from repro.kernels.decode_attn.kernel import decode_attention_pallas

    b, h, s, dh = 1, 2, 64, 32
    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    pos = np.asarray([10], np.int32)
    out1 = np.asarray(decode_attention_pallas(*map(jnp.asarray, (q, k, v, pos)),
                                              tk=32, interpret=True))
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 10:] = 999.0
    v2[:, :, 10:] = -999.0
    out2 = np.asarray(decode_attention_pallas(*map(jnp.asarray, (q, k2, v2, pos)),
                                              tk=32, interpret=True))
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- ssd scan


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 64, 32, 64), (1, 100, 2, 32, 16, 32), (1, 512, 3, 16, 64, 128),
])
def test_ssd_scan(b, s, h, p, n, chunk):
    from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
    from repro.kernels.ssd_scan.ref import ssd_chunked_xla, ssd_ref

    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = (0.001 + rng.random((b, s, h)) * 0.1).astype(np.float32)
    A = (-rng.random(h) * 2 - 0.1).astype(np.float32)
    Bm = rng.normal(size=(b, s, n)).astype(np.float32)
    Cm = rng.normal(size=(b, s, n)).astype(np.float32)
    ref, _ = ssd_ref(*map(jnp.asarray, (x, dt, A, Bm, Cm)))
    ref = np.asarray(ref)
    xla = np.asarray(ssd_chunked_xla(*map(jnp.asarray, (x, dt, A, Bm, Cm)), chunk=chunk))
    pal = np.asarray(ssd_scan_pallas(*map(jnp.asarray, (x, dt, A, Bm, Cm)),
                                     chunk=chunk, interpret=True))
    np.testing.assert_allclose(xla, ref, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(pal, ref, rtol=5e-3, atol=5e-3)


def test_ssd_final_state_matches_sequential():
    from repro.kernels.ssd_scan.ref import ssd_final_state, ssd_ref

    b, s, h, p, n = 1, 96, 2, 16, 8
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = (0.01 + rng.random((b, s, h)) * 0.05).astype(np.float32)
    A = (-rng.random(h) - 0.1).astype(np.float32)
    Bm = rng.normal(size=(b, s, n)).astype(np.float32)
    Cm = rng.normal(size=(b, s, n)).astype(np.float32)
    _, final = ssd_ref(*map(jnp.asarray, (x, dt, A, Bm, Cm)))
    est = ssd_final_state(*map(jnp.asarray, (x, dt, A, Bm)))
    np.testing.assert_allclose(np.asarray(est), np.asarray(final), rtol=1e-4, atol=1e-4)
