"""OLAP executor: numpy oracle vs seg_agg (XLA + interpret) paths, and
SQL-semantics corner cases."""
import numpy as np
import pytest  # noqa: F401
from _hyp import given, settings, st

from repro.core.sql_canon import SQLCanonicalizer
from repro.olap.executor import OlapExecutor


def test_all_intents_numpy_vs_xla(ssb_small, tlc_small, tpcds_small):
    """The kernel-dispatch path must equal the independent numpy oracle for
    every canonical intent of every workload."""
    for wl in (ssb_small, tlc_small, tpcds_small):
        canon = SQLCanonicalizer(wl.schema)
        ex_np = OlapExecutor(wl.dataset, impl="numpy")
        ex_xla = OlapExecutor(wl.dataset, impl="xla")
        for intent in wl.intents:
            sig = canon.canonicalize(intent.sql)
            a = ex_np.execute(sig)
            b = ex_xla.execute(sig)
            assert a.equals(b, ordered=bool(sig.order_by)), intent.id


def test_interpret_kernel_path(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    ex_np = OlapExecutor(ssb_small.dataset, impl="numpy")
    ex_pl = OlapExecutor(ssb_small.dataset, impl="interpret")
    for intent in ssb_small.intents[:4]:
        sig = canon.canonicalize(intent.sql)
        assert ex_np.execute(sig).equals(ex_pl.execute(sig)), intent.id


def test_empty_groups_absent(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    ex = OlapExecutor(ssb_small.dataset, impl="numpy")
    sig = canon.canonicalize(
        "SELECT c_region, COUNT(*) AS n FROM lineorder "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "WHERE lo_quantity > 9999 GROUP BY c_region")
    assert ex.execute(sig).num_rows == 0


def test_global_aggregate_single_row(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    ex = OlapExecutor(ssb_small.dataset, impl="numpy")
    sig = canon.canonicalize("SELECT SUM(lo_revenue) AS r FROM lineorder")
    t = ex.execute(sig)
    assert t.num_rows == 1
    expected = float(np.sum(ssb_small.dataset.fact.columns["lo_revenue"].data))
    assert abs(float(t.columns["m0"][0]) - expected) / expected < 1e-9


def test_having_order_limit(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    ex = OlapExecutor(ssb_small.dataset, impl="numpy")
    sig = canon.canonicalize(
        "SELECT c_nation, SUM(lo_revenue) AS r FROM lineorder "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "GROUP BY c_nation HAVING SUM(lo_revenue) > 0 ORDER BY r DESC LIMIT 5")
    t = ex.execute(sig)
    assert t.num_rows == 5
    vals = t.columns["m0"]
    assert all(vals[i] >= vals[i + 1] for i in range(len(vals) - 1))


@settings(max_examples=20, deadline=None)
@given(
    qty=st.integers(1, 50),
    op=st.sampled_from(["<", "<=", ">", ">="]),
    year=st.integers(1992, 1998),
)
def test_filter_property_vs_oracle(qty, op, year):
    """Executor results == direct numpy computation for arbitrary filters."""
    wl = _wl()
    canon = SQLCanonicalizer(wl.schema)
    ex = OlapExecutor(wl.dataset, impl="xla")
    sig = canon.canonicalize(
        f"SELECT SUM(lo_revenue) AS r, COUNT(*) AS n FROM lineorder "
        f"JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        f"WHERE lo_quantity {op} {qty} AND d_year = {year}")
    t = ex.execute(sig)
    f = wl.dataset.fact.columns
    years = wl.dataset.fact_aligned("dates.d_year")
    m = {"<": np.less, "<=": np.less_equal, ">": np.greater, ">=": np.greater_equal}
    mask = m[op](f["lo_quantity"].data, qty) & (years == year)
    np.testing.assert_allclose(float(t.columns["m0"][0]),
                               float(f["lo_revenue"].data[mask].sum()), rtol=1e-6)
    assert int(t.columns["m1"][0]) == int(mask.sum())


_CACHE = {}


def _wl():
    if "wl" not in _CACHE:
        from repro.workloads import ssb

        _CACHE["wl"] = ssb.build(n_fact=3000, seed=3)
    return _CACHE["wl"]
