import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _clear_faults():
    """No chaos plan leaks across tests: deactivate any installed fault plan
    (and reset the env-plan cache/counters) after every test."""
    yield
    from repro.resilience import faults

    faults.clear()


@pytest.fixture(scope="session")
def ssb_small():
    from repro.workloads import ssb

    return ssb.build(n_fact=4000, seed=0)


@pytest.fixture(scope="session")
def tlc_small():
    from repro.workloads import nyc_tlc

    return nyc_tlc.build(n_fact=4000, seed=1)


@pytest.fixture(scope="session")
def tpcds_small():
    from repro.workloads import tpcds

    return tpcds.build(n_fact=4000, seed=2)
