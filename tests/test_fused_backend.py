"""Device-resident fused backend: cross-checks of ``execute``/``execute_batch``
on the JAX paths (xla + interpret) against the independent numpy oracle over
randomized SSB/TPC-DS signatures, including NaN-bearing measures, empty-mask
groups, and the single-launch property (via the seg_agg launch-count probe).
"""
import numpy as np
import pytest

from repro.core.sql_canon import SQLCanonicalizer
from repro.kernels.seg_agg import ops as seg_ops
from repro.olap.executor import OlapExecutor

J = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
     "JOIN customer ON lineorder.lo_custkey = customer.c_key "
     "JOIN part ON lineorder.lo_partkey = part.p_key ")

_MEASURES = [
    "SUM(lo_revenue)", "AVG(lo_quantity)", "COUNT(*)", "COUNT(lo_discount)",
    "MIN(lo_supplycost)", "MAX(lo_revenue)", "SUM(lo_extendedprice * lo_discount)",
    "AVG(lo_revenue - lo_supplycost)",
]
_LEVELS = [[], ["c_region"], ["c_nation"], ["c_region", "p_mfgr"], ["d_year"]]
_FILTERS = [
    "", "WHERE d_year = 1994", "WHERE lo_quantity < 25",
    "WHERE c_region = 'ASIA' AND lo_discount >= 2",
    "WHERE d_year >= 1993 AND d_year <= 1995 AND lo_quantity != 30",
    "WHERE c_region IN ('ASIA', 'EUROPE') AND lo_quantity > 10",
]


def _random_sql(rng) -> str:
    ms = list(rng.choice(_MEASURES, size=rng.integers(1, 4), replace=False))
    lv = _LEVELS[rng.integers(len(_LEVELS))]
    fl = _FILTERS[rng.integers(len(_FILTERS))]
    cols = ", ".join(lv + [f"{m} AS m{i}" for i, m in enumerate(ms)])
    group = f" GROUP BY {', '.join(lv)}" if lv else ""
    return f"SELECT {cols} FROM lineorder {J}{fl}{group}"


def test_fused_matches_oracle_randomized_ssb(ssb_small):
    rng = np.random.default_rng(11)
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    fused = OlapExecutor(ssb_small.dataset, impl="xla")
    for _ in range(25):
        sig = canon.canonicalize(_random_sql(rng))
        assert fused.execute(sig).equals(oracle.execute(sig)), sig.canonical_json()


def test_fused_matches_oracle_all_intents(ssb_small, tpcds_small):
    """Every canonical workload intent through the fused device path."""
    for wl in (ssb_small, tpcds_small):
        canon = SQLCanonicalizer(wl.schema)
        oracle = OlapExecutor(wl.dataset, impl="numpy")
        fused = OlapExecutor(wl.dataset, impl="xla")
        for intent in wl.intents:
            sig = canon.canonicalize(intent.sql)
            a = oracle.execute(sig)
            b = fused.execute(sig)
            assert a.equals(b, ordered=bool(sig.order_by)), intent.id


def test_fused_interpret_path(ssb_small):
    """Filter-fused Pallas kernel (interpret mode) inside the executor."""
    rng = np.random.default_rng(3)
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    fused = OlapExecutor(ssb_small.dataset, impl="interpret")
    for _ in range(5):
        sig = canon.canonicalize(_random_sql(rng))
        assert fused.execute(sig).equals(oracle.execute(sig)), sig.canonical_json()


def test_single_launch_for_sum_count_avg(ssb_small):
    """All SUM/COUNT/AVG measures of a query ride one seg_agg launch."""
    canon = SQLCanonicalizer(ssb_small.schema)
    ex = OlapExecutor(ssb_small.dataset, impl="xla")
    sig = canon.canonicalize(
        "SELECT c_region, SUM(lo_revenue) AS r, AVG(lo_quantity) AS q, "
        "COUNT(*) AS n, COUNT(lo_discount) AS c, SUM(lo_supplycost) AS s "
        f"FROM lineorder {J}WHERE d_year = 1994 GROUP BY c_region")
    ex.execute(sig)  # warm device caches
    seg_ops.reset_launch_count()
    ex.execute(sig)
    assert seg_ops.launch_count() == 1
    # adding MIN/MAX costs exactly one more fused launch (negated-MAX trick)
    sig2 = canon.canonicalize(
        "SELECT c_region, SUM(lo_revenue) AS r, MIN(lo_quantity) AS lo, "
        f"MAX(lo_quantity) AS hi FROM lineorder {J}GROUP BY c_region")
    ex.execute(sig2)
    seg_ops.reset_launch_count()
    ex.execute(sig2)
    assert seg_ops.launch_count() == 2


def test_legacy_path_launches_per_measure(ssb_small):
    """The seed baseline really is per-measure (what the benchmark compares)."""
    canon = SQLCanonicalizer(ssb_small.schema)
    ex = OlapExecutor(ssb_small.dataset, impl="xla", fused=False)
    sig = canon.canonicalize(
        "SELECT c_region, SUM(lo_revenue) AS r, AVG(lo_quantity) AS q, "
        f"COUNT(*) AS n FROM lineorder {J}GROUP BY c_region")
    seg_ops.reset_launch_count()
    assert ex.execute(sig).equals(
        OlapExecutor(ssb_small.dataset, impl="numpy").execute(sig))
    assert seg_ops.launch_count() == 3  # count col + SUM + AVG


def test_execute_batch_matches_execute(ssb_small):
    """Dashboard refresh: same levels+measures, different filters — one
    shared scan, single launch, per-signature results identical to
    ``execute`` and the oracle."""
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    ex = OlapExecutor(ssb_small.dataset, impl="xla")
    sigs = [canon.canonicalize(
        f"SELECT c_nation, SUM(lo_revenue) AS r, COUNT(*) AS n, AVG(lo_quantity) AS q "
        f"FROM lineorder {J}WHERE d_year = {y} GROUP BY c_nation")
        for y in (1992, 1993, 1994, 1995, 1996)]
    sigs.append(canon.canonicalize(
        f"SELECT c_nation, SUM(lo_revenue) AS r, COUNT(*) AS n, AVG(lo_quantity) AS q "
        f"FROM lineorder {J}WHERE c_region IN ('ASIA', 'AMERICA') GROUP BY c_nation"))
    ex.execute_batch(sigs)  # warm
    seg_ops.reset_launch_count()
    rows_before = ex.rows_scanned
    tables = ex.execute_batch(sigs)
    assert seg_ops.launch_count() == 1  # SUM/COUNT/AVG only: one shared launch
    assert ex.rows_scanned - rows_before == ssb_small.dataset.fact.num_rows
    for sig, t in zip(sigs, tables):
        assert t.equals(oracle.execute(sig)), sig.canonical_json()


def test_execute_batch_mixed_shapes(ssb_small):
    """Signatures with different levels/measures still come back correct
    (heterogeneous groups fall back per-shape)."""
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    ex = OlapExecutor(ssb_small.dataset, impl="xla")
    sqls = [
        f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder {J}WHERE d_year = 1994 GROUP BY c_region",
        f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder {J}WHERE d_year = 1995 GROUP BY c_region",
        f"SELECT p_mfgr, MIN(lo_supplycost) AS c, MAX(lo_supplycost) AS d FROM lineorder {J}GROUP BY p_mfgr",
        f"SELECT SUM(lo_revenue) AS r FROM lineorder {J}WHERE lo_quantity > 45",
    ]
    sigs = [canon.canonicalize(s) for s in sqls]
    for sig, t in zip(sigs, ex.execute_batch(sigs)):
        assert t.equals(oracle.execute(sig)), sig.canonical_json()


@pytest.fixture(scope="module")
def ssb_nan():
    """SSB data with NaNs injected into a measure column (before any device
    upload, so both paths see identical data)."""
    from repro.workloads import ssb

    wl = ssb.build(n_fact=3000, seed=13)
    rng = np.random.default_rng(0)
    rev = wl.dataset.fact.columns["lo_revenue"].data
    rev[rng.random(len(rev)) < 0.05] = np.nan
    return wl


def test_nan_measures_match_oracle(ssb_nan):
    canon = SQLCanonicalizer(ssb_nan.schema)
    oracle = OlapExecutor(ssb_nan.dataset, impl="numpy")
    sqls = [
        f"SELECT c_region, SUM(lo_revenue) AS r, COUNT(lo_revenue) AS n FROM lineorder {J}GROUP BY c_region",
        f"SELECT c_nation, AVG(lo_revenue) AS a, MIN(lo_revenue) AS lo, MAX(lo_revenue) AS hi "
        f"FROM lineorder {J}WHERE d_year = 1994 GROUP BY c_nation",
        f"SELECT SUM(lo_revenue) AS r FROM lineorder {J}WHERE lo_quantity <= 20",
    ]
    for impl in ("xla", "interpret"):
        ex = OlapExecutor(ssb_nan.dataset, impl=impl)
        for s in sqls:
            sig = canon.canonicalize(s)
            assert ex.execute(sig).equals(oracle.execute(sig)), (impl, s)
    # batch with NaNs: shared-scan path is NaN-safe too
    ex = OlapExecutor(ssb_nan.dataset, impl="xla")
    sigs = [canon.canonicalize(
        f"SELECT c_region, SUM(lo_revenue) AS r, COUNT(*) AS n FROM lineorder "
        f"{J}WHERE d_year = {y} GROUP BY c_region") for y in (1994, 1995)]
    for sig, t in zip(sigs, ex.execute_batch(sigs)):
        assert t.equals(oracle.execute(sig))


def test_nan_rows_and_not_equal_semantics(ssb_nan):
    """Numpy filter semantics around NaN on the fused paths: ``!=`` keeps
    NaN rows (NaN != v is True); ordinary comparisons drop them."""
    canon = SQLCanonicalizer(ssb_nan.schema)
    oracle = OlapExecutor(ssb_nan.dataset, impl="numpy")
    sqls = [
        f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
        "WHERE lo_revenue != 100 GROUP BY c_region",
        f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
        "WHERE lo_revenue > 100 GROUP BY c_region",
    ]
    for impl in ("xla", "interpret"):
        ex = OlapExecutor(ssb_nan.dataset, impl=impl)
        for s in sqls:
            sig = canon.canonicalize(s)
            assert ex.execute(sig).equals(oracle.execute(sig)), (impl, s)


def test_nan_filterless_pallas_path(ssb_nan):
    """Filterless aggregate over NaN-bearing data on the Pallas (interpret)
    path: NaN must stay confined to its own group, not poison the tile."""
    canon = SQLCanonicalizer(ssb_nan.schema)
    oracle = OlapExecutor(ssb_nan.dataset, impl="numpy")
    ex = OlapExecutor(ssb_nan.dataset, impl="interpret")
    for s in (
        # fine-grained grouping: many NaN-free groups, so tile-wide NaN
        # spreading (the 0*NaN matmul failure mode) can't hide
        f"SELECT c_city, SUM(lo_revenue) AS r, SUM(lo_quantity) AS q "
        f"FROM lineorder {J}GROUP BY c_city",
        "SELECT SUM(lo_quantity) AS q FROM lineorder",
    ):
        sig = canon.canonicalize(s)
        assert ex.execute(sig).equals(oracle.execute(sig)), s


def test_batch_union_columns_keep_nan_rows(ssb_nan):
    """Batch union predicates: a signature that never filters a NaN-bearing
    column must still count that column's NaN rows (the union filler has to
    accept everything, not just non-NaN values)."""
    canon = SQLCanonicalizer(ssb_nan.schema)
    oracle = OlapExecutor(ssb_nan.dataset, impl="numpy")
    ex = OlapExecutor(ssb_nan.dataset, impl="xla")
    sigs = [canon.canonicalize(
        f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
        "WHERE lo_revenue > 100 GROUP BY c_region"),
        canon.canonicalize(
        f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
        "WHERE d_year = 1994 GROUP BY c_region")]
    for sig, t in zip(sigs, ex.execute_batch(sigs)):
        assert t.equals(oracle.execute(sig)), sig.canonical_json()


def test_empty_mask_groups(ssb_small):
    """Filters that wipe out every row (or whole groups) behave like SQL:
    the groups are absent, the global aggregate keeps its single row."""
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    for impl in ("xla", "interpret"):
        ex = OlapExecutor(ssb_small.dataset, impl=impl)
        sig = canon.canonicalize(
            f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
            "WHERE lo_quantity > 9999 GROUP BY c_region")
        assert ex.execute(sig).num_rows == 0
        glob = canon.canonicalize(
            "SELECT SUM(lo_revenue) AS r, COUNT(*) AS n FROM lineorder "
            f"{J}WHERE lo_quantity > 9999")
        t = ex.execute(glob)
        assert t.num_rows == 1
        assert t.equals(oracle.execute(glob))


def test_f32_inexact_predicates_fall_back_exact():
    """Filters on columns/values outside the f32-exact lattice (>2^24 ints)
    must not produce false matches: the fused path detects them and switches
    to the exact host-evaluated mask while keeping the fused launch."""
    from repro.workloads import ssb

    wl = ssb.build(n_fact=2000, seed=21)
    q = wl.dataset.fact.columns["lo_quantity"].data
    # 16777219 is not f32-representable (rounds to 16777220)
    big = np.where(q > 25, 16777219, 16777220).astype(q.dtype)
    wl.dataset.fact.columns["lo_quantity"].data = big
    canon = SQLCanonicalizer(wl.schema)
    oracle = OlapExecutor(wl.dataset, impl="numpy")
    for impl in ("xla", "interpret"):
        ex = OlapExecutor(wl.dataset, impl=impl)
        for cond in ("= 16777220", "= 16777219", "!= 16777220", "< 16777220"):
            sig = canon.canonicalize(
                f"SELECT c_region, COUNT(*) AS n FROM lineorder {J}"
                f"WHERE lo_quantity {cond} GROUP BY c_region")
            assert ex.execute(sig).equals(oracle.execute(sig)), (impl, cond)
        # single fused launch is preserved on the host-mask fallback
        from repro.kernels.seg_agg import ops as seg_ops

        sig = canon.canonicalize(
            f"SELECT c_region, SUM(lo_revenue) AS r, COUNT(*) AS n "
            f"FROM lineorder {J}WHERE lo_quantity = 16777220 GROUP BY c_region")
        ex.execute(sig)
        seg_ops.reset_launch_count()
        ex.execute(sig)
        assert seg_ops.launch_count() == 1


def test_count_distinct_on_fused_path(ssb_small):
    """COUNT(DISTINCT ...) mixes the host-exact path into a fused query."""
    canon = SQLCanonicalizer(ssb_small.schema)
    oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
    ex = OlapExecutor(ssb_small.dataset, impl="xla")
    sig = canon.canonicalize(
        "SELECT c_region, COUNT(DISTINCT lo_custkey) AS u, SUM(lo_revenue) AS r "
        f"FROM lineorder {J}WHERE d_year = 1994 GROUP BY c_region")
    assert ex.execute(sig).equals(oracle.execute(sig))


def test_device_dataset_uploads_once(ssb_small):
    """Repeated queries reuse the device-resident columns: the DeviceDataset
    store stops growing after the first execution of a given shape."""
    ex = OlapExecutor(ssb_small.dataset, impl="xla")
    canon = SQLCanonicalizer(ssb_small.schema)
    sig = canon.canonicalize(
        f"SELECT c_region, SUM(lo_revenue) AS r FROM lineorder {J}"
        "WHERE d_year = 1994 GROUP BY c_region")
    ex.execute(sig)
    n_entries = len(ex.dev._store)
    for _ in range(3):
        ex.execute(sig)
    assert len(ex.dev._store) == n_entries
