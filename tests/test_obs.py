"""Observability plane: tracing (sampling, propagation, completeness),
the metrics registry + exposition, the cache-lifecycle audit log, and the
``python -m repro.obs`` CLI.  The cross-thread tests pin the tentpole's
propagation contract: follower requests coalesced onto a single-flight
leader link back to the leader's trace, partition scans and write-behind
spills land under the originating request, and every stage a result's
provenance proves it passed through has a matching span — clean and under
injected chaos."""
import json
import threading
import time

import pytest

from repro.obs import (BUCKET_BOUNDS, AuditLog, LogHistogram, MetricsRegistry,
                       ObsConfig, ObsPlane, PIPELINE_STAGES, Tracer, adopt,
                       child_span, current_ctx, span_ctx, trace_completeness)
from repro.obs.__main__ import main as obs_main
from repro.olap.executor import OlapExecutor
from repro.service import CacheService, QueryRequest
from repro.service import pipeline as _pipeline

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")


def sql_region(measures="SUM(lo_revenue) AS r", where=""):
    w = f"WHERE {where} " if where else ""
    return (f"SELECT c_region, {measures} "
            f"FROM lineorder {JOINS}{w}GROUP BY c_region")


def mk_service(wl, obs=None, *, backend=None, **tenant_kw):
    svc = CacheService(obs=obs)
    svc.register_tenant(
        "t", schema=wl.schema,
        backend=backend or OlapExecutor(wl.dataset, impl="numpy"),
        **tenant_kw)
    return svc


# ------------------------------------------------------------ log histogram


class TestLogHistogram:
    def test_quantile_proper_rank_no_p95_bias(self):
        """Regression: the old deque-percentile computed index
        ``int(0.95 * n)`` which over-reads the tail for small n.  The
        histogram interpolates rank ``q * (n - 1)`` within log buckets:
        for 100 identical-bucket samples p50 and p95 agree, and for a
        two-point distribution p95 must stay in the lower bucket until q
        actually crosses the rank."""
        h = LogHistogram()
        for _ in range(99):
            h.observe(1.0)
        h.observe(1000.0)
        # rank 0.95 * 99 = 94.05 < 99: still firmly in the 1ms bucket
        assert h.quantile(0.95) < 3.0
        # only the maximum rank reaches the outlier's bucket
        assert h.quantile(1.0) > 500.0

    def test_observe_quantile_mean(self):
        h = LogHistogram()
        assert h.quantile(0.5) == 0.0 and h.mean == 0.0
        for v in (1.0, 2.0, 4.0, 8.0):
            h.observe(v)
        assert h.count == 4
        assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
        assert 2.0 < h.mean < 5.0

    def test_bucket_bounds_monotone(self):
        assert all(a < b for a, b in zip(BUCKET_BOUNDS, BUCKET_BOUNDS[1:]))

    def test_to_dict(self):
        h = LogHistogram()
        h.observe(3.0)
        d = h.to_dict()
        assert d["count"] == 1 and d["sum"] == pytest.approx(3.0)
        assert d["p50"] <= d["p95"] <= d["p99"]


# ---------------------------------------------------------------- registry


class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests", labelnames=("tenant",))
        c.inc(tenant="a")
        c.inc(2, tenant="b")
        assert c.value(tenant="a") == 1 and c.value(tenant="b") == 2
        g = reg.gauge("depth", "queue depth")
        g.set(7)
        g.inc(-2)
        assert g.value() == 5
        h = reg.histogram("lat_ms", "latency", labelnames=("stage",))
        h.observe(1.5, stage="lookup")
        assert h.value(stage="lookup").count == 1

    def test_get_or_create_is_idempotent_and_typed(self):
        reg = MetricsRegistry()
        a = reg.counter("x_total", "x")
        assert reg.counter("x_total", "x") is a
        with pytest.raises(ValueError):
            reg.gauge("x_total", "x")

    def test_render_prometheus(self):
        reg = MetricsRegistry(namespace="repro")
        reg.counter("hits_total", "cache hits",
                    labelnames=("tenant",)).inc(3, tenant="t")
        reg.histogram("lat_ms", "latency").observe(2.0)
        text = reg.render_prometheus()
        assert '# TYPE repro_hits_total counter' in text
        assert 'repro_hits_total{tenant="t"} 3' in text
        assert '# TYPE repro_lat_ms histogram' in text
        assert 'repro_lat_ms_count 1' in text
        assert 'le="+Inf"' in text

    def test_render_json(self):
        reg = MetricsRegistry()
        reg.gauge("depth", "d").set(4)
        data = reg.render_json()
        json.dumps(data)  # must be wire-serializable as-is
        by_name = {m["name"]: m for m in data["metrics"]}
        assert by_name["repro_depth"]["type"] == "gauge"
        assert by_name["repro_depth"]["samples"][0]["value"] == 4


# ------------------------------------------------------------------ tracer


class TestTracer:
    def test_disabled_returns_none(self):
        tr = Tracer(enabled=False)
        assert tr.start_trace() is None

    def test_sample_all(self):
        tr = Tracer(enabled=True, sample_rate=1.0)
        assert all(tr.start_trace() is not None for _ in range(10))
        assert tr.stats()["sampled"] == 10 and tr.stats()["seen"] == 10

    def test_sample_rate_pacing(self):
        tr = Tracer(enabled=True, sample_rate=0.01)
        got = [tr.start_trace() for _ in range(400)]
        assert sum(t is not None for t in got) == 4  # exactly 1 in 100
        assert tr.stats()["seen"] == 400

    def test_ring_bounded(self):
        tr = Tracer(enabled=True, sample_rate=1.0, ring_capacity=8)
        t = tr.start_trace()
        for i in range(20):
            t.record(f"s{i}")
        assert len(tr.spans()) == 8
        assert tr.stats()["spans_emitted"] == 20

    def test_jsonl_sink(self, tmp_path):
        sink = str(tmp_path / "trace.jsonl")
        tr = Tracer(enabled=True, sample_rate=1.0, sink_path=sink)
        t = tr.start_trace()
        t.record("hello", attrs={"k": 1})
        tr.close()
        recs = [json.loads(x) for x in open(sink)]
        assert recs and recs[0]["name"] == "hello"
        assert recs[0]["trace"] == t.trace_id

    def test_cross_thread_adoption(self):
        """current_ctx captured on the submitting thread + adopt in the
        worker body parents the worker's span under the submitter's."""
        tr = Tracer(enabled=True, sample_rate=1.0)
        t = tr.start_trace()
        with span_ctx(t, "parent", parent_id=t.root_id):
            ctx = current_ctx()

            def worker():
                with adopt(ctx), child_span("child", attrs={"i": 1}):
                    pass

            th = threading.Thread(target=worker)
            th.start()
            th.join()
        spans = {s["name"]: s for s in tr.spans()}
        assert spans["child"]["parent"] == spans["parent"]["span"]
        assert spans["child"]["trace"] == t.trace_id

    def test_child_span_without_ctx_is_noop(self):
        with child_span("orphan"):
            pass  # no installed context: must not raise, records nothing


# --------------------------------------------------------------- audit log


class TestAuditLog:
    def test_emit_events_counts(self):
        au = AuditLog()
        au.emit("put", "k1", tenant="t", nbytes=10)
        au.emit("hit", "k1", tenant="t")
        au.emit("hit", "k2", tenant="t")
        assert au.counts() == {"put": 1, "hit": 2}
        assert [e["event"] for e in au.events(key="k1")] == ["put", "hit"]
        assert au.stats()["emitted"] == 3

    def test_ring_bounded_and_sink_complete(self, tmp_path):
        sink = str(tmp_path / "audit.jsonl")
        au = AuditLog(capacity=4, sink_path=sink)
        for i in range(10):
            au.emit("put", f"k{i}")
        assert len(au.events()) == 4  # ring keeps the tail
        au.close()
        assert len([x for x in open(sink) if x.strip()]) == 10  # sink: all


# --------------------------------------------------- config + stage parity


class TestObsConfig:
    def test_defaults_are_metrics_only(self):
        plane = ObsPlane(ObsConfig())
        assert not plane.tracer.enabled and plane.audit is None
        assert plane.tracer.start_trace() is None

    def test_disabled_and_full(self):
        assert ObsPlane(ObsConfig.disabled()).audit is None
        full = ObsPlane(ObsConfig.full(sample_rate=1.0))
        assert full.tracer.enabled and full.audit is not None

    def test_pipeline_stages_pinned(self):
        """The obs mirror of the stage tuple must track the pipeline's
        (obs stays import-light, so the tuple is duplicated on purpose)."""
        assert PIPELINE_STAGES == _pipeline.STAGES


# -------------------------------------------------------- service tracing


class TestServiceTracing:
    def test_warm_hit_traced_end_to_end(self, ssb_small):
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0))
        miss = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        hit = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        assert miss.status == "miss" and hit.status == "hit_exact"
        assert miss.trace_id and hit.trace_id
        assert miss.trace_id != hit.trace_id
        names = {s["name"] for s in svc.obs.tracer.spans(miss.trace_id)}
        # the miss passed through every stage its provenance records (plain
        # SQL never enters the NL gate); execute.backend is the live backend
        # span nested under the root
        assert {"canonicalize", "validate", "lookup", "execute",
                "store", "request", "execute.backend"} <= names
        comp = trace_completeness([miss, hit], svc.obs.tracer)
        assert comp["ok"] and comp["traces_checked"] == 2

    def test_unsampled_requests_have_no_trace(self, ssb_small):
        svc = mk_service(ssb_small, ObsConfig(tracing=True,
                                              sample_rate=0.0001))
        res = [svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
               for _ in range(5)]
        assert all(r.trace_id is None for r in res)
        # unsampled results serialize without trace keys at all
        assert "trace_id" not in res[0].to_dict()

    def test_result_serializes_trace_ids(self, ssb_small):
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0))
        r = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        d = r.to_dict()
        assert d["trace_id"] == r.trace_id and d["span_id"] == r.span_id

    def test_partition_spans_adopted(self, ssb_small):
        be = OlapExecutor(ssb_small.dataset, impl="numpy", partitions=2)
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0),
                         backend=be)
        r = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        spans = svc.obs.tracer.spans(r.trace_id)
        parts = [s for s in spans if s["name"] == "execute.partition"]
        backend = [s for s in spans if s["name"] == "execute.backend"]
        assert len(parts) == 2 and len(backend) == 1
        assert all(p["parent"] == backend[0]["span"] for p in parts)

    def test_spill_span_adopted(self, ssb_small, tmp_path):
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0),
                         shards=2)
        svc.open(str(tmp_path / "store"))
        r = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        # the spill is write-behind: wait for the worker's span rather than
        # closing immediately (close()'s final sync spill would supersede the
        # pending job, and the superseding job carries no request context)
        spills = []
        deadline = time.time() + 5.0
        while not spills and time.time() < deadline:
            spills = [s for s in svc.obs.tracer.spans(r.trace_id)
                      if s["name"] == "store.spill"]
            if not spills:
                time.sleep(0.01)
        svc.close()
        assert spills and spills[0]["attrs"]["ok"] is True
        assert spills[0]["attrs"]["key"] == r.signature.key()

    def test_single_flight_storm_links_follower_spans(self, ssb_small):
        """8 threads storm one cold signature at sample rate 1.0: every
        follower's plan span carries the leader's trace/span id, and the
        leader's trace records one flight.adopt link per follower."""
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0),
                         shards=4)
        n = 8
        results = [None] * n
        barrier = threading.Barrier(n)

        def worker(i):
            barrier.wait()
            results[i] = svc.submit(QueryRequest(sql=sql_region(),
                                                 tenant="t"))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(r is not None and r.ok for r in results)
        followers = [r for r in results if r.deduped]
        if not followers:
            pytest.skip("storm produced no coalesced followers this run")
        tracer = svc.obs.tracer
        leader_traces = set()
        for f in followers:
            plan = [s for s in tracer.spans(f.trace_id)
                    if s["name"] == "plan"]
            assert plan, "follower has no plan span"
            attrs = plan[0]["attrs"]
            assert "adopted_from_trace" in attrs
            assert attrs["adopted_from_trace"] != f.trace_id
            leader_traces.add(attrs["adopted_from_trace"])
        # the adoption links point at real leader traces that recorded one
        # flight.adopt span per follower
        for lt in leader_traces:
            adopts = [s for s in tracer.spans(lt)
                      if s["name"] == "flight.adopt"]
            linked = {s["attrs"]["follower_trace"] for s in adopts}
            assert {f.trace_id for f in followers
                    if f.trace_id} <= linked | {None}
        comp = trace_completeness(results, tracer)
        assert comp["ok"], comp["missing"]


# ------------------------------------------------------- service metrics


class TestServiceMetrics:
    def test_prometheus_exposition(self, ssb_small):
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0))
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        text = svc.metrics()
        assert 'repro_service_requests_total{tenant="t"} 2' in text
        assert 'repro_cache_hits_exact_total{tenant="t"} 1' in text
        assert 'repro_stage_latency_ms_count{stage="lookup",tenant="t"}' \
            in text
        assert "repro_traces_sampled_total 2" in text
        assert "repro_audit_events_total" in text

    def test_json_exposition_and_bad_fmt(self, ssb_small):
        svc = mk_service(ssb_small)
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        data = svc.metrics(fmt="json")
        json.dumps(data)  # must be wire-serializable as-is
        names = {m["name"] for m in data["metrics"]}
        assert "repro_service_requests_total" in names
        assert "repro_stage_latency_ms" in names
        with pytest.raises(ValueError):
            svc.metrics(fmt="xml")

    def test_breaker_and_shard_gauges(self, ssb_small):
        svc = mk_service(ssb_small, shards=2)
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        text = svc.metrics()
        assert 'repro_breaker_state{dependency="backend",tenant="t"} 0' \
            in text
        assert 'repro_shard_entries{shard="0",tenant="t"}' in text

    def test_stage_percentiles_from_histograms(self, ssb_small):
        svc = mk_service(ssb_small)
        for i in range(4):
            svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        t = svc.tenant("t")
        pct = t.stats.stage_percentiles()
        assert "lookup" in pct
        assert pct["lookup"]["p50_ms"] <= pct["lookup"]["p95_ms"]
        assert pct["lookup"]["n"] == 4
        d = t.stats.to_dict()
        assert "stages_ms" in d and "lookup" in d["stages_ms"]


# ------------------------------------------------------- audit integration


class TestAuditIntegration:
    def test_hit_and_put_audited_with_labels(self, ssb_small):
        svc = mk_service(ssb_small, ObsConfig.full(sample_rate=1.0))
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
        events = svc.obs.audit.events()
        kinds = [e["event"] for e in events]
        assert kinds.count("put") == 1 and kinds.count("hit") == 1
        hit = next(e for e in events if e["event"] == "hit")
        assert hit["tenant"] == "t" and hit["tier"] == "hot"
        assert hit["request_origin"] == "sql" and hit["hits"] >= 1

    def test_sharded_eviction_audited_with_policy_inputs(self, ssb_small):
        from repro.core import SemanticCache

        cache = SemanticCache(ssb_small.schema,
                              level_mapper=ssb_small.dataset.level_mapper(),
                              capacity=2)
        svc = CacheService(obs=ObsConfig.full(sample_rate=1.0))
        svc.register_tenant(
            "t", schema=ssb_small.schema,
            backend=OlapExecutor(ssb_small.dataset, impl="numpy"),
            cache=cache)
        for i in range(4):
            svc.submit(QueryRequest(
                sql=sql_region(where=f"d_year = {1992 + i}"), tenant="t"))
        evts = [e for e in svc.obs.audit.events()
                if e["event"] in ("evict", "demote")]
        assert evts, "capacity pressure must audit evictions"
        e = evts[0]
        # policy inputs ride along so `explain` can narrate the decision
        for k in ("score", "decayed_hits", "cost_ms", "nbytes", "policy",
                  "reason"):
            assert k in e, f"missing policy input {k}"


# -------------------------------------------------------------------- CLI


@pytest.fixture()
def obs_sinks(ssb_small, tmp_path):
    tsink = str(tmp_path / "trace.jsonl")
    asink = str(tmp_path / "audit.jsonl")
    svc = mk_service(ssb_small, ObsConfig.full(
        sample_rate=1.0, trace_sink=tsink, audit_sink=asink))
    r0 = svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
    svc.submit(QueryRequest(sql=sql_region(), tenant="t"))
    svc.obs.close()
    return tsink, asink, r0


class TestObsCli:
    def test_summarize(self, obs_sinks, capsys):
        tsink, _, r0 = obs_sinks
        assert obs_main(["summarize", tsink]) == 0
        out = capsys.readouterr().out
        assert f"trace {r0.trace_id}" in out
        assert "execute.backend" in out

    def test_summarize_missing_trace(self, obs_sinks, capsys):
        tsink, _, _ = obs_sinks
        assert obs_main(["summarize", tsink, "--trace", "nope"]) == 1

    def test_explain(self, obs_sinks, capsys):
        _, asink, r0 = obs_sinks
        key = r0.signature.key()
        assert obs_main(["explain", asink, "--key", key]) == 0
        out = capsys.readouterr().out
        assert "put" in out and "hit" in out
        assert "never left the cache" in out

    def test_explain_unknown_key(self, obs_sinks):
        _, asink, _ = obs_sinks
        assert obs_main(["explain", asink, "--key", "zzz"]) == 1

    def test_false_hits_clean(self, obs_sinks, capsys):
        _, asink, _ = obs_sinks
        assert obs_main(["false-hits", asink]) == 0
        out = capsys.readouterr().out
        assert "0 false" in out

    def test_false_hits_detects_liveness_violation(self, tmp_path, capsys):
        bad = tmp_path / "bad.jsonl"
        evts = [
            {"ts": 1.0, "event": "put", "key": "k1"},
            {"ts": 2.0, "event": "drop", "key": "k1",
             "reason": "explicit_invalidation"},
            {"ts": 3.0, "event": "hit", "key": "k1"},
        ]
        bad.write_text("\n".join(json.dumps(e) for e in evts))
        assert obs_main(["false-hits", str(bad)]) == 2
        assert "FALSE HIT" in capsys.readouterr().out

    def test_demoted_entry_still_live_for_false_hit_audit(self, tmp_path):
        ok = tmp_path / "demoted.jsonl"
        evts = [
            {"ts": 1.0, "event": "put", "key": "k1"},
            {"ts": 2.0, "event": "demote", "key": "k1", "tier": "hot"},
            {"ts": 3.0, "event": "hit", "key": "k1", "tier": "cold"},
        ]
        ok.write_text("\n".join(json.dumps(e) for e in evts))
        assert obs_main(["false-hits", str(ok)]) == 0
