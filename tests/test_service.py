"""Batch-first service API: tenant/scope isolation, the staged pipeline's
provenance/timing envelope, batch miss execution through the fused backend
(launch-count probe + numpy-oracle cross-check), in-flight dedup, and the
lifecycle methods."""
import json

import pytest

from repro.core import MemoizedNL, SafetyPolicy, SemanticCache, SimulatedLLM
from repro.core.metrics import GovernedMetric, MetricLayer
from repro.core.signature import Measure
from repro.kernels.seg_agg.ops import launch_count, reset_launch_count
from repro.olap.executor import OlapExecutor
from repro.service import CacheService, QueryRequest

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")

BASE = ("SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, "
        "COUNT(*) AS n, MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi "
        f"FROM lineorder {JOINS}")

# A 12-tile dashboard refresh: shared grouping + measures, differing
# filters/time-windows (the acceptance-criteria scenario).
DASHBOARD = (
    [BASE + f"WHERE d_year = {y} GROUP BY c_region"
     for y in (1992, 1993, 1994, 1995, 1996, 1997)]
    + [BASE + f"WHERE lo_date >= '{a}' AND lo_date < '{b}' GROUP BY c_region"
       for a, b in (("1992-01-01", "1992-07-01"), ("1993-02-01", "1994-02-01"),
                    ("1995-06-01", "1996-06-01"))]
    + [BASE + f"WHERE lo_quantity {op} GROUP BY c_region"
       for op in ("< 10", "< 25", "> 40")]
)


def mk_service(wl, impl="numpy", name="default", **tenant_kw):
    backend = OlapExecutor(wl.dataset, impl=impl)
    cache = SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper())
    svc = CacheService()
    tenant = svc.register_tenant(name, schema=wl.schema, backend=backend,
                                 cache=cache, **tenant_kw)
    return svc, tenant, backend


class TestIsolation:
    def test_same_sql_two_scopes_never_share(self, ssb_small):
        """Strict scope isolation in the key space: identical SQL text under
        two scopes must both miss and occupy distinct cache entries."""
        svc, tenant, _ = mk_service(ssb_small)
        sql = DASHBOARD[0]
        r_a = svc.submit(QueryRequest(sql=sql, scope="team-a"))
        r_b = svc.submit(QueryRequest(sql=sql, scope="team-b"))
        assert r_a.status == "miss" and r_b.status == "miss"
        assert r_a.signature.key() != r_b.signature.key()
        assert len(tenant.cache) == 2
        # repeat within a scope is a hit; the other scope stays isolated
        assert svc.submit(QueryRequest(sql=sql, scope="team-a")).status == "hit_exact"
        assert svc.submit(QueryRequest(sql=sql, scope="team-c")).status == "miss"

    def test_tenants_have_disjoint_caches(self, ssb_small):
        svc = CacheService()
        backends = [OlapExecutor(ssb_small.dataset, impl="numpy") for _ in range(2)]
        for name, be in zip(("bi", "notebook"), backends):
            svc.register_tenant(name, schema=ssb_small.schema, backend=be)
        sql = DASHBOARD[0]
        assert svc.submit(QueryRequest(sql=sql, tenant="bi")).status == "miss"
        # same text, other tenant: its own cache, so a miss again
        assert svc.submit(QueryRequest(sql=sql, tenant="notebook")).status == "miss"
        assert len(svc.tenant("bi").cache) == 1
        assert len(svc.tenant("notebook").cache) == 1
        assert svc.tenant("bi").stats.backend_executions == 1

    def test_unknown_tenant_rejected(self, ssb_small):
        svc, _, _ = mk_service(ssb_small, name="only")
        with pytest.raises(KeyError):
            svc.submit(QueryRequest(sql="SELECT COUNT(*) FROM lineorder",
                                    tenant="nope"))

    def test_duplicate_tenant_rejected(self, ssb_small):
        svc, _, _ = mk_service(ssb_small, name="t")
        with pytest.raises(ValueError):
            svc.register_tenant("t", schema=ssb_small.schema,
                                backend=OlapExecutor(ssb_small.dataset, impl="numpy"))


class TestBatchMissExecution:
    def test_batch_matches_serial_oracle(self, ssb_small):
        """execute_batch-served misses must be row-identical to the serial
        execute path (independent numpy oracle)."""
        svc, tenant, backend = mk_service(ssb_small, impl="auto")
        results = svc.submit_batch(
            [QueryRequest(sql=q) for q in DASHBOARD])
        oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
        assert all(r.status == "miss" for r in results)
        assert all(r.batched for r in results)
        for r in results:
            direct = oracle.execute(r.signature)
            assert r.table.equals(direct, ordered=bool(r.signature.order_by))

    def test_dashboard_refresh_two_launches(self, ssb_small):
        """Acceptance criterion: a 12-query dashboard refresh (shared
        grouping, differing filters/windows) executes all misses via
        OlapExecutor.execute_batch in <= 2 fused launches per agg block —
        in fact one ``seg_agg_batch_blocks`` launch covering both the fused
        SUM/COUNT/AVG block and the shared MIN/MAX block."""
        svc, tenant, backend = mk_service(ssb_small, impl="auto")
        reqs = [QueryRequest(sql=q) for q in DASHBOARD]
        assert len(reqs) == 12
        reset_launch_count()
        results = svc.submit_batch(reqs)
        # 1 on the xla+rect path (both blocks share the launch); 2 on the
        # per-block pallas/interpret fallback — either way <= 2
        assert launch_count() <= 2
        assert backend.batch_calls == 1 and backend.batch_groups == 1
        assert tenant.stats.batched_misses == 12
        assert [r.status for r in results] == ["miss"] * 12
        # a second refresh is all exact hits: no further launches
        reset_launch_count()
        again = svc.submit_batch(reqs)
        assert launch_count() == 0
        assert all(r.status == "hit_exact" for r in again)

    def test_single_launch_for_sum_only_block(self, ssb_small):
        base = ("SELECT c_region, SUM(lo_revenue) AS rev, COUNT(*) AS n "
                f"FROM lineorder {JOINS}")
        reqs = [QueryRequest(sql=base + f"WHERE d_year = {y} GROUP BY c_region")
                for y in (1992, 1993, 1994, 1995)]
        svc, _, _ = mk_service(ssb_small, impl="auto")
        reset_launch_count()
        results = svc.submit_batch(reqs)
        assert launch_count() == 1  # sum-only block: nothing else to fuse
        assert all(r.status == "miss" and r.batched for r in results)

    def test_inflight_dedup_one_execution(self, ssb_small):
        """Identical in-flight signatures within a batch share one backend
        execution; every requester still gets the table."""
        svc, tenant, backend = mk_service(ssb_small)
        sql = DASHBOARD[0]
        variant = sql.replace("SELECT", "select")  # same canonical intent
        results = svc.submit_batch(
            [QueryRequest(sql=sql), QueryRequest(sql=variant),
             QueryRequest(sql=DASHBOARD[1])])
        assert backend.executions == 2  # 3 requests, 2 unique intents
        assert tenant.stats.deduped_misses == 1
        assert [r.status for r in results] == ["miss"] * 3
        assert results[1].deduped and not results[0].deduped
        assert results[0].table.equals(results[1].table)
        assert len(tenant.cache) == 2  # stored once per unique intent

    def test_mixed_batch_hits_and_misses(self, ssb_small):
        svc, tenant, backend = mk_service(ssb_small)
        svc.submit(QueryRequest(sql=DASHBOARD[0]))
        n0 = backend.executions
        results = svc.submit_batch([QueryRequest(sql=q) for q in DASHBOARD[:3]])
        assert results[0].status == "hit_exact"
        assert [r.status for r in results[1:]] == ["miss", "miss"]
        assert backend.executions == n0 + 2


class TestPipelineEnvelope:
    def test_provenance_and_timings(self, ssb_small):
        svc, _, _ = mk_service(ssb_small)
        r = svc.submit(QueryRequest(sql=DASHBOARD[0]))
        assert r.provenance[0] == "canonicalize:sql"
        assert "lookup:miss" in r.provenance and "store" in r.provenance
        for stage in ("canonicalize", "validate", "lookup", "execute"):
            assert stage in r.timings_ms
        assert json.dumps(r.to_dict())  # serializable

    def test_bypass_envelope_out_of_scope_sql(self, ssb_small):
        svc, tenant, backend = mk_service(ssb_small)
        r = svc.submit(QueryRequest(sql="SELECT a FROM t UNION SELECT b FROM u"))
        assert r.status == "bypass" and tenant.stats.bypasses == 1
        assert backend.executions == 1  # still executed raw on the backend
        assert len(tenant.cache) == 0

    def test_request_needs_exactly_one_form(self):
        with pytest.raises(ValueError):
            QueryRequest()
        with pytest.raises(ValueError):
            QueryRequest(sql="SELECT 1", nl="one")

    def test_read_only_never_stores(self, ssb_small):
        svc, tenant, _ = mk_service(ssb_small)
        r = svc.submit(QueryRequest(sql=DASHBOARD[0], read_only=True))
        assert r.status == "miss" and r.table is not None
        assert len(tenant.cache) == 0

    def test_refresh_reexecutes_and_restores(self, ssb_small):
        svc, tenant, backend = mk_service(ssb_small)
        svc.submit(QueryRequest(sql=DASHBOARD[0]))
        r = svc.submit(QueryRequest(sql=DASHBOARD[0], refresh=True))
        assert r.status == "miss"  # skipped the cache read
        assert "lookup:skipped_refresh" in r.provenance
        assert backend.executions == 2
        assert len(tenant.cache) == 1

    def test_signature_and_metric_requests(self, ssb_small):
        svc, tenant, _ = mk_service(ssb_small)
        sig = tenant.sql_canon.canonicalize(DASHBOARD[0])
        r = svc.submit(QueryRequest(signature=sig))
        assert r.status == "miss" and r.origin == "signature"
        # governed metric sharing the same measures occupies a disjoint key
        metrics = MetricLayer((GovernedMetric(
            "finance.revenue", ssb_small.schema.name,
            (Measure("SUM", "lineorder.lo_revenue"),)),))
        tenant.metrics = metrics
        rm = svc.submit(QueryRequest(metric_id="finance.revenue",
                                     levels=("customer.c_region",)))
        assert rm.status == "miss" and rm.origin == "metric"
        assert rm.signature.metric_id == "finance.revenue"
        rm2 = svc.submit(QueryRequest(metric_id="finance.revenue",
                                      levels=("customer.c_region",)))
        assert rm2.status == "hit_exact"
        r_unknown = svc.submit(QueryRequest(metric_id="nope.metric"))
        assert r_unknown.status == "bypass"

    def test_nl_batch_canonicalization(self, tlc_small):
        svc, tenant, _ = mk_service(
            tlc_small, name="tlc",
            nl=MemoizedNL(SimulatedLLM(tlc_small.vocab, model="oracle")),
            policy=SafetyPolicy.balanced(
                tlc_small.spatial_ambiguous,
                qualified=("pickup zone", "dropoff zone", "pickup borough",
                           "dropoff borough")))
        texts = ["total earnings by pickup borough in 2024",
                 "average fare by payment type in 2024"]
        results = svc.submit_batch(
            [QueryRequest(nl=t, tenant="tlc") for t in texts])
        assert all(r.status in ("miss", "bypass") for r in results)
        served = [r for r in results if r.status == "miss"]
        assert served and all(
            "canonicalize:nl_batched" in r.provenance for r in served)
        # singleton NL requests go through the plain entry point
        r = svc.submit(QueryRequest(nl=texts[0], tenant="tlc"))
        assert r.hit


class TestLifecycle:
    def test_advance_snapshot_invalidates_and_rebumps(self, ssb_small):
        svc, tenant, _ = mk_service(ssb_small)
        svc.submit(QueryRequest(sql=DASHBOARD[7]))  # closed 1993-straddling window
        svc.submit(QueryRequest(sql=DASHBOARD[11]))  # no window: open-ended rule
        rep = svc.advance_snapshot("default", "snap1",
                                   "1993-05-01", "1993-06-01")
        assert rep.dropped == 2  # window intersects + windowless entry
        assert rep.unaffected == 0 and rep.refreshed == 0
        assert tenant.snapshot_id == "snap1"

    def test_invalidate_schema_change_drops_all(self, ssb_small):
        svc, tenant, _ = mk_service(ssb_small)
        svc.submit_batch([QueryRequest(sql=q) for q in DASHBOARD[:3]])
        assert svc.invalidate(schema_change=True) == 3
        assert len(tenant.cache) == 0

    def test_warm_uses_live_pipeline(self, ssb_small):
        svc, tenant, backend = mk_service(ssb_small)
        reqs = [QueryRequest(sql=q) for q in DASHBOARD[:4]]
        warmed = svc.warm(reqs)
        assert all(r.status == "miss" for r in warmed)
        assert len(tenant.cache) == 4
        # the warmed entries serve live traffic
        assert all(r.hit for r in svc.submit_batch(reqs))
        with pytest.raises(ValueError):
            svc.warm([QueryRequest(sql=DASHBOARD[0], read_only=True)])

    def test_stats_endpoint_serializable(self, ssb_small):
        svc, _, _ = mk_service(ssb_small)
        svc.submit_batch([QueryRequest(sql=q) for q in DASHBOARD[:2]])
        payload = json.dumps(svc.stats())
        d = svc.stats("default")
        assert d["service"]["requests"] == 2
        assert d["cache"]["misses"] == 2 and "hit_rate" in d["cache"]
        assert payload


class TestStatsDataclasses:
    def test_cachestats_hits_is_property(self, ssb_small):
        cache = SemanticCache(ssb_small.schema)
        assert cache.stats.hits == 0  # property, not a bound method
        assert cache.stats.lookups == 0
        assert cache.stats.hit_rate == 0.0
        d = cache.stats.to_dict()
        assert d["hits"] == 0 and d["hit_rate"] == 0.0
        assert json.dumps(d)
