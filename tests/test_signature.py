"""Signature canonicalization invariants (unit + hypothesis properties)."""
import json

import pytest
from _hyp import given, settings, st

from repro.core.signature import (
    Filter, HavingClause, Measure, Signature, TimeWindow, signature_from_json,
)


def sig(**kw):
    base = dict(schema="s", measures=(Measure("SUM", "f.x"),))
    base.update(kw)
    return Signature(**base)


class TestCanonicalForm:
    def test_levels_sorted(self):
        a = sig(levels=("b.y", "a.x"))
        b = sig(levels=("a.x", "b.y"))
        assert a.key() == b.key()

    def test_filter_order_irrelevant(self):
        f1 = Filter("t.a", "=", "x")
        f2 = Filter("t.b", ">", 3)
        assert sig(filters=(f1, f2)).key() == sig(filters=(f2, f1)).key()

    def test_literal_normalization(self):
        assert Filter("t.a", "=", 3.0).val == 3
        assert Filter("t.a", "=", "  x ").val == "x"
        assert Filter("t.a", "in", [3, 1, 2]).val == (1, 2, 3)

    def test_measure_order_significant(self):
        m1, m2 = Measure("SUM", "f.x"), Measure("COUNT", "*")
        assert sig(measures=(m1, m2)).key() != sig(measures=(m2, m1)).key()

    def test_distinct_count_folds(self):
        m = Measure("COUNT", "f.x", distinct=True)
        assert m.agg == "COUNT_DISTINCT"
        assert not m.composable()

    def test_composable(self):
        assert Measure("SUM", "f.x").composable()
        assert Measure("MIN", "f.x").composable()
        assert not Measure("AVG", "f.x").composable()

    def test_time_window_validation(self):
        with pytest.raises(ValueError):
            TimeWindow("2024-02-01", "2024-01-01")
        with pytest.raises(ValueError):
            TimeWindow("not-a-date", "2024-01-01")

    def test_requires_measure(self):
        with pytest.raises(ValueError):
            Signature(schema="s", measures=())

    def test_unknown_agg_rejected(self):
        with pytest.raises(ValueError):
            Measure("MEDIAN", "f.x")

    def test_json_roundtrip(self):
        s = sig(
            levels=("a.x",),
            filters=(Filter("t.a", "in", ["p", "q"]),),
            time_window=TimeWindow("2024-01-01", "2024-04-01"),
            having=(HavingClause(0, ">", 10),),
            limit=None,
        )
        s2 = signature_from_json(json.loads(s.canonical_json()))
        assert s2.key() == s.key()

    def test_scope_isolates(self):
        assert sig(scope="tenant_a").key() != sig(scope="tenant_b").key()
        assert sig(scope="tenant_a").key() != sig().key()


# ----------------------------------------------------------- property tests

filters_st = st.lists(
    st.builds(
        Filter,
        col=st.sampled_from(["t.a", "t.b", "u.c"]),
        op=st.sampled_from(["=", "<", ">", "<=", ">=", "!="]),
        val=st.one_of(st.integers(-100, 100), st.text(
            alphabet="abcxyz", min_size=1, max_size=4)),
    ),
    max_size=5,
)


@settings(max_examples=60, deadline=None)
@given(filters=filters_st, levels=st.permutations(["a.x", "b.y", "c.z"]))
def test_permutation_invariance(filters, levels):
    import random

    shuffled = list(filters)
    random.Random(0).shuffle(shuffled)
    s1 = sig(filters=tuple(filters), levels=tuple(levels))
    s2 = sig(filters=tuple(shuffled), levels=tuple(sorted(levels)))
    assert s1.key() == s2.key()


@settings(max_examples=60, deadline=None)
@given(filters=filters_st)
def test_canonical_json_deterministic(filters):
    s1 = sig(filters=tuple(filters))
    s2 = signature_from_json(json.loads(s1.canonical_json()))
    assert s1.canonical_json() == s2.canonical_json()
    assert len(s1.key()) == 64  # sha-256 hex
