"""Cache behaviour + correctness-preserving derivations.

The key property throughout: ANY table served by the cache must equal the
backend's direct execution of the requested signature — zero false hits.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import SemanticCache, Signature, Measure, Filter, TimeWindow
from repro.core.sql_canon import SQLCanonicalizer
from repro.olap.executor import OlapExecutor


@pytest.fixture(scope="module")
def env(ssb_small):
    canon = SQLCanonicalizer(ssb_small.schema)
    backend = OlapExecutor(ssb_small.dataset, impl="numpy")
    return ssb_small, canon, backend


def fresh_cache(wl, **kw):
    return SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(), **kw)


J = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
     "JOIN customer ON lineorder.lo_custkey = customer.c_key ")


def q(levels, where="d_year = 1994"):
    cols = ", ".join(levels)
    return (f"SELECT {cols}, SUM(lo_revenue) AS r, COUNT(*) AS n "
            f"FROM lineorder {J}WHERE {where} GROUP BY {cols}")


class TestExactAndLRU:
    def test_exact_hit(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        sig = canon.canonicalize(q(["c_region"]))
        cache.put(sig, backend.execute(sig))
        r = cache.lookup(sig)
        assert r.status == "hit_exact"
        assert r.table.equals(backend.execute(sig))

    def test_lru_eviction(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl, capacity=2)
        sigs = [canon.canonicalize(q(["c_region"], f"d_year = {y}"))
                for y in (1994, 1995, 1996)]
        for s in sigs:
            cache.put(s, backend.execute(s))
        assert len(cache) == 2
        assert cache.lookup(sigs[0]).status == "miss"  # evicted (oldest)
        assert cache.lookup(sigs[2]).status == "hit_exact"

    def test_lru_touch_on_hit(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl, capacity=2)
        s1 = canon.canonicalize(q(["c_region"], "d_year = 1994"))
        s2 = canon.canonicalize(q(["c_region"], "d_year = 1995"))
        s3 = canon.canonicalize(q(["c_region"], "d_year = 1996"))
        cache.put(s1, backend.execute(s1))
        cache.put(s2, backend.execute(s2))
        cache.lookup(s1)  # refresh s1
        cache.put(s3, backend.execute(s3))  # evicts s2, not s1
        assert cache.lookup(s1).status == "hit_exact"
        assert cache.lookup(s2).status == "miss"


class TestRollup:
    def test_rollup_matches_backend(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        fine = canon.canonicalize(q(["c_city", "c_nation"]))
        cache.put(fine, backend.execute(fine))
        for coarse_cols in (["c_nation"], ["c_region"], ["c_city"]):
            coarse = canon.canonicalize(q(coarse_cols))
            r = cache.lookup(coarse)
            assert r.status == "hit_rollup", coarse_cols
            assert r.table.equals(backend.execute(coarse)), coarse_cols

    def test_rollup_count_and_minmax(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        sql_fine = (
            "SELECT c_city, COUNT(*) AS n, MIN(lo_quantity) AS mn, "
            "MAX(lo_quantity) AS mx FROM lineorder "
            f"{J}WHERE d_year = 1994 GROUP BY c_city")
        sql_coarse = sql_fine.replace("c_city", "c_nation")
        fine = canon.canonicalize(sql_fine)
        coarse = canon.canonicalize(sql_coarse)
        cache.put(fine, backend.execute(fine))
        r = cache.lookup(coarse)
        assert r.status == "hit_rollup"
        assert r.table.equals(backend.execute(coarse))

    def test_avg_not_rollupable(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        fine = canon.canonicalize(
            f"SELECT c_city, AVG(lo_quantity) a FROM lineorder {J}"
            "WHERE d_year = 1994 GROUP BY c_city")
        coarse = canon.canonicalize(
            f"SELECT c_nation, AVG(lo_quantity) a FROM lineorder {J}"
            "WHERE d_year = 1994 GROUP BY c_nation")
        cache.put(fine, backend.execute(fine))
        assert cache.lookup(coarse).status == "miss"

    def test_drilldown_never_served(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        coarse = canon.canonicalize(q(["c_region"]))
        fine = canon.canonicalize(q(["c_nation"]))
        cache.put(coarse, backend.execute(coarse))
        assert cache.lookup(fine).status == "miss"

    def test_filter_mismatch_blocks_rollup(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        fine = canon.canonicalize(q(["c_city"], "d_year = 1994"))
        other = canon.canonicalize(q(["c_nation"], "d_year = 1995"))
        cache.put(fine, backend.execute(fine))
        assert cache.lookup(other).status == "miss"

    def test_order_by_disables_derivation(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        fine = canon.canonicalize(q(["c_city", "c_nation"]))
        cache.put(fine, backend.execute(fine))
        topk = canon.canonicalize(
            f"SELECT c_nation, SUM(lo_revenue) AS r, COUNT(*) AS n FROM lineorder {J}"
            "WHERE d_year = 1994 GROUP BY c_nation ORDER BY r DESC LIMIT 3")
        assert cache.lookup(topk).status == "miss"


class TestFilterDown:
    def test_filterdown_matches_backend(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        superset = canon.canonicalize(q(["c_region", "c_nation"]))
        cache.put(superset, backend.execute(superset))
        tight = canon.canonicalize(
            q(["c_region", "c_nation"], "d_year = 1994 AND c_region = 'ASIA'"))
        r = cache.lookup(tight)
        assert r.status == "hit_filterdown"
        assert r.table.equals(backend.execute(tight))

    def test_missing_attr_blocks_filterdown(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        superset = canon.canonicalize(q(["c_nation"]))
        cache.put(superset, backend.execute(superset))
        # c_region is not among cached columns -> not derivable
        tight = canon.canonicalize(q(["c_nation"], "d_year = 1994 AND c_region = 'ASIA'"))
        assert cache.lookup(tight).status == "miss"


class TestInvalidation:
    def test_closed_windows_survive_disjoint_updates(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        closed = canon.canonicalize(q(["c_region"], "d_year = 1994"))
        cache.put(closed, backend.execute(closed))
        dropped = cache.invalidate_snapshot("1998-01-01", "1998-02-01")
        assert dropped == 0
        assert cache.lookup(closed).status == "hit_exact"

    def test_intersecting_window_dropped(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        s = canon.canonicalize(q(["c_region"], "d_year = 1994"))
        cache.put(s, backend.execute(s))
        assert cache.invalidate_snapshot("1994-06-01", "1994-07-01") == 1
        assert cache.lookup(s).status == "miss"

    def test_open_ended_always_dropped(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        sig = Signature(
            schema=wl.schema.name, measures=(Measure("SUM", "lineorder.lo_revenue"),),
            time_window=TimeWindow("1998-12-01", "1998-12-31", open_ended=True))
        cache.put(sig, backend.execute(sig))
        assert cache.invalidate_snapshot("1992-01-01", "1992-01-02") == 1

    def test_no_window_dropped_conservatively(self, env):
        wl, canon, backend = env
        cache = fresh_cache(wl)
        s = canon.canonicalize(
            "SELECT c_region, SUM(lo_revenue) r FROM lineorder "
            "JOIN customer ON lineorder.lo_custkey = customer.c_key GROUP BY c_region")
        cache.put(s, backend.execute(s))
        assert cache.invalidate_snapshot("1992-01-01", "1992-01-02") == 1


# ------------------------------------------------------ hypothesis property


_ENV_CACHE = {}


def _get_env():
    if "env" not in _ENV_CACHE:
        from repro.workloads import ssb

        wl = ssb.build(n_fact=4000, seed=0)
        _ENV_CACHE["env"] = (
            wl, SQLCanonicalizer(wl.schema), OlapExecutor(wl.dataset, impl="numpy"))
    return _ENV_CACHE["env"]


@settings(max_examples=25, deadline=None)
@given(
    year=st.sampled_from([1993, 1994, 1995]),
    fine=st.sampled_from(["c_city", "c_nation"]),
    data=st.data(),
)
def test_rollup_equals_backend_property(year, fine, data):
    wl, canon, backend = _get_env()
    hierarchy = {"c_city": ["c_nation", "c_region"], "c_nation": ["c_region"]}
    coarse = data.draw(st.sampled_from(hierarchy[fine]))
    cache = fresh_cache(wl)
    fsig = canon.canonicalize(q([fine], f"d_year = {year}"))
    csig = canon.canonicalize(q([coarse], f"d_year = {year}"))
    cache.put(fsig, backend.execute(fsig))
    r = cache.lookup(csig)
    assert r.status == "hit_rollup"
    assert r.table.equals(backend.execute(csig))


class TestPersistence:
    def test_spill_and_warm(self, tmp_path):
        from repro.core.cache import load_cache, save_cache

        wl, canon, backend = _get_env()
        cache = fresh_cache(wl)
        sigs = [canon.canonicalize(q(["c_region"], f"d_year = {y}"))
                for y in (1994, 1995)]
        for s in sigs:
            cache.put(s, backend.execute(s))
        n = save_cache(cache, str(tmp_path / "spill"))
        assert n == 2
        warm = fresh_cache(wl)
        assert load_cache(warm, str(tmp_path / "spill")) == 2
        for s in sigs:
            r = warm.lookup(s)
            assert r.status == "hit_exact"
            assert r.table.equals(backend.execute(s))

    def test_tampered_entry_refused(self, tmp_path):
        import json

        from repro.core.cache import load_cache, save_cache

        wl, canon, backend = _get_env()
        cache = fresh_cache(wl)
        s = canon.canonicalize(q(["c_region"]))
        cache.put(s, backend.execute(s))
        save_cache(cache, str(tmp_path / "spill"))
        mpath = tmp_path / "spill" / "manifest.json"
        m = json.loads(mpath.read_text())
        m[0]["signature"]["levels"] = ["customer.c_nation"]  # key mismatch now
        mpath.write_text(json.dumps(m))
        warm = fresh_cache(wl)
        assert load_cache(warm, str(tmp_path / "spill")) == 0


class TestComposeAndMetrics:
    def test_composed_derivation_matches_backend(self):
        """Beyond-paper: cached (nation, region) answers 'by region WHERE
        nation=X' via filter-down o roll-up — still zero-false-hit."""
        wl, canon, backend = _get_env()
        cache = fresh_cache(wl, enable_compose=True)
        superset = canon.canonicalize(q(["c_nation", "c_city"]))
        cache.put(superset, backend.execute(superset))
        tight = canon.canonicalize(
            q(["c_city"], "d_year = 1994 AND c_nation = 'ASIA_NATION_0'"))
        r = cache.lookup(tight)
        assert r.status == "hit_compose"
        assert r.table.equals(backend.execute(tight))

    def test_compose_disabled_by_default(self):
        wl, canon, backend = _get_env()
        cache = fresh_cache(wl)
        superset = canon.canonicalize(q(["c_nation", "c_city"]))
        cache.put(superset, backend.execute(superset))
        tight = canon.canonicalize(
            q(["c_city"], "d_year = 1994 AND c_nation = 'ASIA_NATION_0'"))
        assert cache.lookup(tight).status == "miss"

    def test_governed_metrics_disambiguate(self):
        from repro.core.metrics import GovernedMetric, MetricLayer
        from repro.core.signature import Measure

        wl, canon, backend = _get_env()
        layer = MetricLayer((
            GovernedMetric("fin.gross_revenue", "ssb",
                           (Measure("SUM", "lineorder.lo_extendedprice"),),
                           aliases=("revenue",)),
            GovernedMetric("fin.net_revenue", "ssb",
                           (Measure("SUM", "lineorder.lo_revenue"),)),
        ))
        a = layer.expand("fin.gross_revenue", levels=("customer.c_region",))
        b = layer.expand("fin.net_revenue", levels=("customer.c_region",))
        assert a.key() != b.key()
        assert a.metric_id == "fin.gross_revenue"
        # alias lookup pins NL 'revenue' to the governed definition
        assert layer.resolve_alias("ssb", "Revenue").metric_id == "fin.gross_revenue"
        # governed and identical ad-hoc signatures occupy disjoint key spaces
        adhoc = a.replace(metric_id=None)
        assert adhoc.key() != a.key()
        # governed entries are cacheable like any other signature
        cache = fresh_cache(wl)
        cache.put(a, backend.execute(a))
        assert cache.lookup(a).status == "hit_exact"
