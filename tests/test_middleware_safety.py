"""End-to-end middleware behaviour: zero false hits, bypass paths, NL safety
gating, adversarial calibration, and the paper's cross-surface reuse."""
import collections
import datetime

import pytest

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,
                        SemanticCacheMiddleware, SimulatedLLM)
from repro.olap.executor import OlapExecutor

QUAL = ("customer region", "supplier region", "customer city", "supplier city",
        "customer nation", "supplier nation", "pickup zone", "dropoff zone",
        "pickup borough", "dropoff borough")


def mk(wl, model="oracle", policy=None, **cache_kw):
    backend = OlapExecutor(wl.dataset, impl="numpy")
    cache = SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper(), **cache_kw)
    llm = MemoizedNL(SimulatedLLM(wl.vocab, model=model))
    policy = policy or SafetyPolicy.balanced(wl.spatial_ambiguous, qualified=QUAL)
    return SemanticCacheMiddleware(wl.schema, backend, cache, nl=llm, policy=policy), backend


class TestZeroFalseHits:
    def test_every_hit_equals_backend(self, ssb_small):
        """The paper's RQ2 invariant, audited per query."""
        mw, backend = mk(ssb_small)
        oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
        false_hits = 0
        for q in ssb_small.queries(sql_variants=6, nl_paraphrases=4):
            r = mw.query_sql(q.text) if q.kind == "sql" else mw.query_nl(q.text)
            if r.hit:
                direct = oracle.execute(r.signature)
                if not r.table.equals(direct, ordered=bool(r.signature.order_by)):
                    false_hits += 1
        assert false_hits == 0

    def test_hierarchical_zero_false_hits(self, ssb_small):
        from repro.workloads import hierarchical

        mw, _ = mk(ssb_small)
        oracle = OlapExecutor(ssb_small.dataset, impl="numpy")
        for q in hierarchical.build_stream(8):
            r = mw.query_sql(q.text)
            if r.hit:
                assert r.table.equals(oracle.execute(r.signature)), q.intent_id


class TestBypass:
    def test_out_of_scope_sql_bypasses(self, ssb_small):
        mw, backend = mk(ssb_small)
        r = mw.query_sql("SELECT a FROM t UNION SELECT b FROM u")
        assert r.status == "bypass"
        assert backend.executions == 1  # still executed on the backend

    def test_invalid_reference_bypasses(self, ssb_small):
        mw, _ = mk(ssb_small)
        r = mw.query_sql("SELECT SUM(no_such_col) FROM lineorder")
        assert r.status == "bypass"
        assert "no_such_col" in (r.bypass_reason or "")

    def test_bypass_never_stores(self, ssb_small):
        mw, _ = mk(ssb_small)
        mw.query_sql("SELECT a FROM t UNION SELECT b FROM u")
        assert len(mw.cache) == 0


class TestNLSafety:
    def test_low_confidence_gated(self, tlc_small):
        mw, _ = mk(tlc_small, model="gpt-4o-mini",
                   policy=SafetyPolicy(confidence_threshold=0.99))
        r = mw.query_nl("total earnings by pickup borough in 2024")
        assert r.status == "bypass"
        assert "confidence" in r.bypass_reason

    def test_relative_time_without_now_gated(self, tlc_small):
        mw, _ = mk(tlc_small)
        r = mw.query_nl("total earnings by pickup borough last month")
        assert r.status == "bypass"

    def test_relative_time_with_now_allowed(self, tlc_small):
        mw, _ = mk(tlc_small)
        r = mw.query_nl("total earnings by pickup borough last month",
                        now=datetime.date(2024, 3, 15))
        assert r.status != "bypass"
        assert r.signature.time_window.open_ended

    def test_spatial_ambiguity_gated(self, tlc_small):
        mw, _ = mk(tlc_small)
        r = mw.query_nl("total earnings by area in 2024")
        assert r.status == "bypass"
        assert "spatial" in r.bypass_reason

    def test_aggword_mismatch_gated(self, tlc_small):
        """Policy with agg-word heuristic rejects a signature whose agg
        contradicts the text."""
        policy = SafetyPolicy.conservative(tlc_small.spatial_ambiguous, QUAL)
        mw, _ = mk(tlc_small, policy=policy)
        # force a wrong-agg signature through a doctored vocab entry
        from repro.core.nl_canon import NLResult
        from repro.core.safety import gate_nl
        from repro.core.signature import Measure, Signature

        sig = Signature(schema="nyc_tlc", measures=(Measure("COUNT", "*"),))
        res = NLResult(sig, 0.9, "{}")
        gate = gate_nl(policy, "average fare by year", res,
                       now=datetime.date(2024, 1, 1))
        assert not gate.allow

    def test_sql_seeded_mode_blocks_nl_stores(self, tlc_small):
        policy = SafetyPolicy(confidence_threshold=None, heuristic_time=False,
                              heuristic_spatial=False, heuristic_aggword=False,
                              sql_seeded_only=True)
        mw, _ = mk(tlc_small, policy=policy)
        r = mw.query_nl("total earnings by pickup borough in 2024")
        assert r.status == "miss"
        assert len(mw.cache) == 0  # read-only for NL

    def test_no_nl_canonicalizer_counts_bypass(self, ssb_small):
        """An NL request on an NL-less deployment is a *counted* bypass:
        stats.bypasses advances and the canonicalize stage is timed, so
        stats never drift from the actual request mix."""
        from repro.core import SemanticCache, SemanticCacheMiddleware

        backend = OlapExecutor(ssb_small.dataset, impl="numpy")
        cache = SemanticCache(ssb_small.schema)
        mw = SemanticCacheMiddleware(ssb_small.schema, backend, cache)  # nl=None
        r = mw.query_nl("total revenue by region")
        assert r.status == "bypass"
        assert "no NL canonicalizer" in r.bypass_reason
        assert mw.stats.bypasses == 1
        assert r.canon_ms >= 0.0
        assert backend.executions == 0  # nothing safe to execute

    def test_cross_surface_hit(self, tlc_small):
        mw, _ = mk(tlc_small)
        sql = ("SELECT pu_borough, SUM(total_amount) AS earnings FROM trips "
               "JOIN zones_pu ON trips.pu_zone_key = zones_pu.zpu_key "
               "JOIN dates ON trips.pickup_date_key = dates.d_key "
               "WHERE d_year = 2024 GROUP BY pu_borough")
        assert mw.query_sql(sql).status == "miss"
        r = mw.query_nl("Show total earnings by pickup borough in 2024")
        assert r.hit
        assert r.source_origin == "sql"
        assert mw.cache.stats.cross_surface_hits == 1


class TestAdversarialCalibration:
    def test_table2_counts(self):
        """The calibrated profiles reproduce Table 2 / Table 5b exactly."""
        from repro.workloads import adversarial, nyc_tlc, ssb, tpcds

        qs = adversarial.build()
        vocabs = {"ssb": ssb.build_vocab(), "nyc_tlc": nyc_tlc.build_vocab(),
                  "tpcds": tpcds.build_vocab()}
        for model, want in [("gpt-4o-mini", (28, 30, 5)),
                            ("claude-3.5-haiku", (38, 25, 0))]:
            llms = {k: SimulatedLLM(v, model=model) for k, v in vocabs.items()}
            res = [llms[q.schema].canonicalize(q.text, now=None) for q in qs]
            sc = adversarial.score(qs, res)
            tot = collections.Counter()
            for b in sc["per_type"].values():
                tot.update(b)
            assert (tot["correct"], tot["wrong"], tot["invalid"]) == want, model

    def test_memoization(self, tlc_small):
        llm = MemoizedNL(SimulatedLLM(tlc_small.vocab))
        llm.canonicalize("total earnings by pickup borough in 2024")
        llm.canonicalize("total earnings by pickup borough in 2024")
        assert llm.calls == 1 and llm.memo_hits == 1
