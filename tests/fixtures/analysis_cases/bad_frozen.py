"""Known-bad interning/immutability usage for tests/test_analysis.py."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Point:
    x: int
    y: int

    def __post_init__(self):
        object.__setattr__(self, "x", int(self.x))  # in-class: allowed


def retag(p: Point) -> None:
    object.__setattr__(p, "x", 0)  # FINDING: immutability (pierces frozen)


def shift(p: Point) -> None:
    p.y = 3  # FINDING: immutability (would raise FrozenInstanceError)


def waived_retag(p: Point) -> None:
    object.__setattr__(p, "y", 1)  # analysis: allow[immutability] test waiver
