"""Known-bad lock ordering: a two-lock AB/BA inversion (direct) and a
cycle closed through a call summary.  tests/test_analysis.py asserts the
lock-order pass reports the cycle."""
import threading


class Inverted:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def forward(self):
        with self._a:
            with self._b:
                pass

    def backward(self):
        with self._b:
            with self._a:  # closes the cycle Inverted._a <-> Inverted._b
                pass


class ViaCall:
    def __init__(self):
        self._outer = threading.Lock()
        self._inner = threading.Lock()

    def take_outer(self):
        with self._inner:
            self.nested()  # summary: nested() acquires _outer under _inner

    def nested(self):
        with self._outer:
            pass

    def take_inner(self):
        with self._outer:
            with self._inner:
                pass
