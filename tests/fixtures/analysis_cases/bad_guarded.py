"""Known-bad lock discipline, exercised by tests/test_analysis.py.

Every violation below is intentional; the golden test asserts the
lock-discipline pass reports exactly these findings (and honors the
waiver).  This module is never imported by production code.
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.hits = 0  # guarded-by: self._lock
        self.items = []  # guarded-by: self._lock
        self.snapshot = None  # guarded-by: external[single-writer protocol]
        self.notes = {}

    def good(self):
        with self._lock:
            self.hits += 1
            self.items.append(1)

    def good_acquire_pairing(self):
        self._lock.acquire()
        self.hits += 1
        self._lock.release()

    def good_external(self):
        self.snapshot = object()

    def bad_plain(self):
        self.hits = 5  # FINDING: guarded-by (plain assign, no lock)

    def bad_aug(self):
        self.hits += 1  # FINDING: guarded-by (compound +=, no lock)

    def bad_mutator(self):
        self.items.append(2)  # FINDING: guarded-by (mutator call, no lock)

    def bad_subscript(self, wrong_lock):
        with wrong_lock:
            self.items[0] = 3  # FINDING: guarded-by (wrong lock held)

    def bad_unannotated(self):
        self.notes["k"] = 1  # FINDING: unannotated-shared-write

    def waived_write(self):
        self.hits = 0  # analysis: allow[guarded-by] deliberate test waiver


class Helper:
    """Caller-holds-lock convention: requires-lock seeds the held set."""

    def __init__(self, counter: Counter):
        self.counter = counter

    def bump(self) -> None:  # requires-lock: self.counter._lock
        self.counter.hits += 1

    def bad_bump(self) -> None:
        self.counter.hits += 1  # FINDING: guarded-by (cross-receiver)
